//! The online experiment: Spark jobs on a Mesos-like cluster — the
//! machinery behind Figures 3–9, generalized to scenario workloads.
//!
//! Wiring: submission queues register frameworks with the [`Master`]; the
//! allocator grants executors (fine- or coarse-grained per
//! [`AllocatorMode`]); executors pull microtasks from their job's driver;
//! task finishes free slots and eventually complete jobs, whose executor
//! resources are released back (possibly staggered — §3.5.3) and trigger
//! new allocation cycles; a sampler records the allocated CPU/mem fractions
//! the figures plot.
//!
//! The workload side is a [`RealizedScenario`]
//! ([`crate::workload::scenario`]): closed queues resubmit on completion
//! (the paper's batches), open queues arrive at pre-realized times
//! (Poisson / bursty / diurnal), agents churn per the realized schedule,
//! and every task duration was fixed at realization — so the same
//! scenario, recorded and replayed, drives any scheduler identically.

use crate::cluster::{ReleaseMode, ServerType};
use crate::error::{Error, Result};
use crate::mesos::allocator::{AllocatorMode, Grant};
use crate::mesos::master::Master;
use crate::mesos::offer::Offer;
use crate::mesos::OfferHandler;
use crate::metrics::DistStats;
use crate::obs::ObsSummary;
use crate::resources::ResVec;
use crate::rng::Rng;
use crate::scheduler::{policy_by_name, KernelKind, NativeScorer, Scorer};
use crate::sim::engine::EventQueue;
use crate::sim::events::{EventKind, JobId};
use crate::sim::trace::TraceRecorder;
use crate::spark::driver::{fill_executor, Dispatch, SpeculationCfg};
use crate::spark::executor::Executor;
use crate::spark::job::SparkJob;
use crate::spark::queue::SubmissionQueue;
use crate::spark::workload::{WorkloadKind, WorkloadSpec};
use crate::workload::arrival::ArrivalProcess;
use crate::workload::churn::{ChurnEvent, ChurnModel};
use crate::workload::scenario::{realize, RealizedScenario};
use std::collections::HashMap;

/// One submission queue's configuration.
#[derive(Debug, Clone)]
pub struct QueueSpec {
    pub workload: WorkloadSpec,
    pub jobs: usize,
    /// How this queue's jobs arrive (closed batch by default).
    pub arrival: ArrivalProcess,
    /// Fair-share weight φ of this queue's frameworks (the paper uses 1).
    /// Threaded through `Master::register_framework` and recorded in the
    /// scenario trace, so weighted runs replay exactly.
    pub weight: f64,
}

impl QueueSpec {
    /// A closed-loop batch queue (the paper's behaviour).
    pub fn closed(workload: WorkloadSpec, jobs: usize) -> Self {
        QueueSpec { workload, jobs, arrival: ArrivalProcess::Closed, weight: 1.0 }
    }

    /// An open queue whose jobs arrive per `arrival`.
    pub fn open(workload: WorkloadSpec, jobs: usize, arrival: ArrivalProcess) -> Self {
        QueueSpec { workload, jobs, arrival, weight: 1.0 }
    }

    /// Builder-style fair-share weight override.
    pub fn weighted(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }
}

/// Full configuration of an online run.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    pub cluster: Vec<ServerType>,
    /// Register agents one-by-one (Fig 9) instead of all up-front.
    pub staged: bool,
    /// Seconds between staged registrations.
    pub stage_interval: f64,
    pub queues: Vec<QueueSpec>,
    /// Scheduler registry name ("drf", "psdsf", …).
    pub policy: String,
    pub mode: AllocatorMode,
    pub seed: u64,
    /// Utilization sampling period (seconds).
    pub sample_dt: f64,
    /// Max staggering of per-executor releases after job completion.
    pub release_jitter: f64,
    /// Mesos' allocation batching interval (`--allocation_interval`):
    /// state changes schedule one deferred allocation cycle this many
    /// seconds later, pooling a completing job's releases.
    pub allocation_interval: f64,
    /// §3.1: released agents handled as a *pool* (batched cycle, agent
    /// selection matters — default) or *sequentially* (each release triggers
    /// its own immediate cycle, so the freed agent is effectively the only
    /// candidate).
    pub release_mode: ReleaseMode,
    pub speculation: SpeculationCfg,
    /// Cluster churn model (realized into a schedule at scenario time).
    pub churn: ChurnModel,
    /// Parallel scoring/argmin shards for the native engine (1 = serial;
    /// results are bit-identical at any count).
    pub shards: usize,
    /// Row-fill kernel for the native engine (`--kernel scalar|batched`;
    /// results are bit-identical either way).
    pub kernel: KernelKind,
    /// Attach the obs flight recorder (CLI `--obs`): decision traces and
    /// cycle-phase timings land in [`OnlineResult::obs`]. Grants are
    /// bit-identical with or without it.
    pub obs: bool,
    /// Safety cutoff (simulated seconds).
    pub max_sim_time: f64,
}

impl OnlineConfig {
    /// The paper's §3.3 set-up: 6 heterogeneous agents, two groups × five
    /// queues × `jobs_per_queue` jobs.
    pub fn paper(policy: &str, mode: AllocatorMode, jobs_per_queue: usize) -> Self {
        let mut queues = Vec::new();
        for _ in 0..5 {
            queues.push(QueueSpec::closed(WorkloadSpec::pi(), jobs_per_queue));
        }
        for _ in 0..5 {
            queues.push(QueueSpec::closed(WorkloadSpec::wordcount(), jobs_per_queue));
        }
        OnlineConfig {
            cluster: ServerType::paper_heterogeneous(),
            staged: false,
            stage_interval: 60.0,
            queues,
            policy: policy.to_string(),
            mode,
            seed: 0x5EED,
            sample_dt: 5.0,
            release_jitter: 0.5,
            allocation_interval: 1.0,
            release_mode: ReleaseMode::Pool,
            speculation: SpeculationCfg::default(),
            churn: ChurnModel::None,
            shards: 1,
            kernel: KernelKind::default(),
            obs: false,
            max_sim_time: 1e7,
        }
    }

    /// §3.6's homogeneous cluster variant.
    pub fn paper_homogeneous(policy: &str, mode: AllocatorMode, jobs_per_queue: usize) -> Self {
        let mut cfg = OnlineConfig::paper(policy, mode, jobs_per_queue);
        cfg.cluster = ServerType::paper_homogeneous();
        cfg
    }

    /// §3.7 / Fig 9: three agents (one per type) registered one by one,
    /// 5 queues × 20 jobs per group.
    pub fn paper_staged(policy: &str, jobs_per_queue: usize) -> Self {
        let mut cfg = OnlineConfig::paper(policy, AllocatorMode::Characterized, jobs_per_queue);
        cfg.cluster = ServerType::paper_staged();
        cfg.staged = true;
        cfg
    }

    /// The scale scenario family unlocked by the dynamic-dimension scoring
    /// core: `agents` heterogeneous servers ([`ServerType::scaled`]) driven
    /// by `queues` concurrent submission queues (alternating Pi/WordCount,
    /// one in-flight job each — so `queues` concurrent frameworks) of
    /// `jobs_per_queue` jobs. `scaled("rpsdsf", mode, 64, 128, 1)` runs a
    /// 64-agent / 128-framework experiment end-to-end; the paper's own
    /// configurations are the `paper*` constructors above.
    pub fn scaled(
        policy: &str,
        mode: AllocatorMode,
        agents: usize,
        queues: usize,
        jobs_per_queue: usize,
    ) -> Self {
        let mut cfg = OnlineConfig::paper(policy, mode, jobs_per_queue);
        cfg.cluster = ServerType::scaled(agents);
        cfg.queues = (0..queues)
            .map(|q| {
                let mut w = if q % 2 == 0 { WorkloadSpec::pi() } else { WorkloadSpec::wordcount() };
                // keep per-job work small: the point is breadth, not depth
                w.tasks_per_job = 8;
                w.max_executors = 2;
                QueueSpec::closed(w, jobs_per_queue)
            })
            .collect();
        cfg
    }

    /// Resolve `--shards auto` / `shards = "auto"`: the detected core
    /// count ([`std::thread::available_parallelism`]), clamped to the
    /// persistent scoring pool's bounds. Config front-ends resolve the
    /// string form through here at parse time, so [`OnlineConfig::shards`]
    /// is always a concrete count.
    pub fn auto_shards() -> usize {
        crate::scheduler::pool::auto_shards()
    }

    /// A small fast configuration for tests.
    pub fn small(policy: &str, mode: AllocatorMode) -> Self {
        let mut cfg = OnlineConfig::paper(policy, mode, 2);
        for q in &mut cfg.queues {
            q.workload.tasks_per_job = 8;
            q.workload.max_executors = 4;
        }
        cfg.queues.truncate(4); // 2 Pi + … keep two of each group
        cfg.queues.remove(2);
        cfg.queues.push(QueueSpec::closed(
            {
                let mut w = WorkloadSpec::wordcount();
                w.tasks_per_job = 8;
                w.max_executors = 4;
                w
            },
            2,
        ));
        cfg
    }
}

/// Hook for running real task compute through the PJRT runtime (the e2e
/// example); the figure sweeps use [`NoCompute`].
pub trait TaskCompute {
    /// Execute the body of one finished task attempt.
    fn run_task(&mut self, kind: WorkloadKind, seed: u64) -> Result<()>;
}

/// Default no-op compute.
pub struct NoCompute;

impl TaskCompute for NoCompute {
    fn run_task(&mut self, _kind: WorkloadKind, _seed: u64) -> Result<()> {
        Ok(())
    }
}

/// Aggregated outcome of one online run.
#[derive(Debug, Clone)]
pub struct OnlineResult {
    pub label: String,
    /// Time the last job finished.
    pub makespan: f64,
    pub jobs_completed: usize,
    pub trace: TraceRecorder,
    pub mean_cpu: f64,
    pub mean_mem: f64,
    pub std_cpu: f64,
    pub std_mem: f64,
    /// Last finish time per submission group.
    pub group_finish: Vec<(String, f64)>,
    /// Allocator cycles run / grants issued (perf accounting).
    pub cycles: u64,
    pub grants: u64,
    /// Tasks executed (incl. speculative winners only).
    pub tasks_done: usize,
    /// Per-job completion time (finish − submission) distribution.
    pub completion: DistStats,
    /// Per-job slowdown (completion / inherent service) distribution.
    pub slowdown: DistStats,
    /// Flight-recorder output ([`OnlineConfig::obs`]): decision events,
    /// per-phase timing histograms and engine counters.
    pub obs: Option<ObsSummary>,
}

/// The online simulator.
pub struct OnlineSim {
    cfg: OnlineConfig,
    master: Master,
    events: EventQueue,
    rng: Rng,
    queues: Vec<SubmissionQueue>,
    churn: Vec<ChurnEvent>,
    jobs: Vec<SparkJob>,
    executors: Vec<Executor>,
    fw_to_job: HashMap<usize, JobId>,
    done_durations: Vec<Vec<f64>>,
    trace: TraceRecorder,
    group_finish: HashMap<&'static str, f64>,
    tasks_done: usize,
    /// An Allocate event is already queued (coalesces triggers).
    alloc_pending: bool,
}

impl OnlineSim {
    pub fn new(cfg: OnlineConfig) -> Result<Self> {
        Self::with_scorer(cfg, Box::new(NativeScorer::new()))
    }

    /// Build with an explicit scoring backend (`--scorer hlo` uses the
    /// PJRT-backed one). Realizes the configured workload live.
    pub fn with_scorer(cfg: OnlineConfig, scorer: Box<dyn Scorer>) -> Result<Self> {
        let scenario = realize(&cfg, "adhoc");
        Self::with_scenario_scorer(cfg, scenario, scorer)
    }

    /// Build from an explicit realized scenario (trace replay).
    pub fn with_scenario(cfg: OnlineConfig, scenario: RealizedScenario) -> Result<Self> {
        Self::with_scenario_scorer(cfg, scenario, Box::new(NativeScorer::new()))
    }

    /// Build from a realized scenario and an explicit scoring backend.
    pub fn with_scenario_scorer(
        cfg: OnlineConfig,
        scenario: RealizedScenario,
        scorer: Box<dyn Scorer>,
    ) -> Result<Self> {
        if scenario.queues.len() != cfg.queues.len() {
            return Err(Error::Config(format!(
                "scenario has {} queues but the configuration has {}",
                scenario.queues.len(),
                cfg.queues.len()
            )));
        }
        if let Some(bad) = scenario.churn.iter().find(|e| e.agent >= cfg.cluster.len()) {
            return Err(Error::Config(format!(
                "scenario churn references agent {} but the cluster has {} agents",
                bad.agent,
                cfg.cluster.len()
            )));
        }
        if scenario.agents != cfg.cluster.len() {
            return Err(Error::Config(format!(
                "scenario was realized for {} agents but the configuration has {} — \
                 refusing to replay against a different cluster",
                scenario.agents,
                cfg.cluster.len()
            )));
        }
        let kinds = cfg.cluster.first().map(|s| s.capacity.len()).unwrap_or(2);
        if scenario.kinds != kinds {
            return Err(Error::Config(format!(
                "scenario was realized with {} resource kinds but the cluster has {kinds}",
                scenario.kinds
            )));
        }
        if let Some(bad) =
            scenario.queues.iter().find(|q| q.spec.executor_demand.len() != kinds)
        {
            return Err(Error::Config(format!(
                "scenario workload '{}' has {} resource dims but the cluster has {kinds}",
                bad.spec.kind.label(),
                bad.spec.executor_demand.len()
            )));
        }
        let policy = policy_by_name(&cfg.policy)?;
        let pool = if cfg.staged {
            crate::cluster::AgentPool::new_staged(&cfg.cluster)
        } else {
            crate::cluster::AgentPool::new(&cfg.cluster)
        };
        let mut master = Master::new(pool, policy, cfg.mode, scorer);
        master.set_shards(cfg.shards.max(1));
        master.set_kernel(cfg.kernel);
        if cfg.obs {
            master.enable_obs(crate::obs::DEFAULT_EVENT_CAPACITY);
        }
        let label = format!("{}/{}", cfg.policy, cfg.mode.label());
        let queues: Vec<SubmissionQueue> = scenario
            .queues
            .into_iter()
            .enumerate()
            .map(|(i, rq)| SubmissionQueue::new(i, rq))
            .collect();
        let rng = Rng::new(cfg.seed);
        Ok(OnlineSim {
            master,
            events: EventQueue::new(),
            rng,
            queues,
            churn: scenario.churn,
            jobs: Vec::new(),
            executors: Vec::new(),
            fw_to_job: HashMap::new(),
            done_durations: Vec::new(),
            trace: TraceRecorder::new(&label),
            group_finish: HashMap::new(),
            tasks_done: 0,
            alloc_pending: false,
            cfg,
        })
    }

    /// Override the oblivious demand-inference rule (ablation bench).
    pub fn set_inference_rule(&mut self, rule: crate::mesos::framework::InferenceRule) {
        self.master.set_inference_rule(rule);
    }

    /// Run to completion with no real compute.
    pub fn run(self) -> Result<OnlineResult> {
        let mut none = NoCompute;
        self.run_with_compute(&mut none)
    }

    /// Run to completion, invoking `compute` for every winning task attempt.
    pub fn run_with_compute(mut self, compute: &mut dyn TaskCompute) -> Result<OnlineResult> {
        // bootstrap: agents, churn, submissions, sampler
        if self.cfg.staged {
            for (k, _) in self.cfg.cluster.iter().enumerate() {
                self.events
                    .schedule(k as f64 * self.cfg.stage_interval, EventKind::AgentUp { agent: k });
            }
        }
        for ev in &self.churn {
            let kind = if ev.up {
                EventKind::AgentUp { agent: ev.agent }
            } else {
                EventKind::AgentDown { agent: ev.agent }
            };
            self.events.schedule(ev.t, kind);
        }
        for q in 0..self.queues.len() {
            if self.queues[q].closed {
                self.events.schedule(0.0, EventKind::JobArrival { queue: q });
            } else {
                let times = self.queues[q].arrivals.clone();
                for t in times {
                    self.events.schedule(t, EventKind::JobArrival { queue: q });
                }
            }
        }
        self.events.schedule(0.0, EventKind::Sample);

        while let Some(ev) = self.events.pop() {
            if ev.time > self.cfg.max_sim_time {
                break;
            }
            let now = ev.time;
            match ev.kind {
                EventKind::AgentUp { agent } => {
                    self.master.agent_up(agent);
                    self.request_allocation();
                }
                EventKind::AgentDown { agent } => {
                    self.master.agent_down(agent);
                }
                EventKind::JobArrival { queue } => self.on_job_arrival(queue, now)?,
                EventKind::Allocate => {
                    self.alloc_pending = false;
                    self.allocate(now)?;
                }
                EventKind::TaskFinish { job, exec, task, attempt, duration } => {
                    self.on_task_finish(job, exec, task, attempt, duration, now, compute)?;
                }
                EventKind::Release { framework, agent, amount, count } => {
                    self.master.release(framework, agent, &amount, count)?;
                    match self.cfg.release_mode {
                        ReleaseMode::Pool => self.request_allocation(),
                        // sequential: the allocator reacts to each release
                        // immediately, before the rest of the job's
                        // executors free up
                        ReleaseMode::Sequential => self.allocate(now)?,
                    }
                }
                EventKind::Sample => {
                    self.trace.sample(now, &self.master.state.pool);
                    if !self.finished() {
                        self.events.schedule_in(self.cfg.sample_dt, EventKind::Sample);
                    }
                }
            }
            if self.finished() && self.events.is_empty() {
                break;
            }
        }
        // final sample after the last (possibly jittered) releases drained,
        // so traces end at zero utilization
        let t_end = self.events.now();
        self.trace.sample(t_end, &self.master.state.pool);

        let makespan = self
            .jobs
            .iter()
            .filter_map(|j| j.finished_at)
            .fold(0.0, f64::max);
        let cpu_summary = self.trace.cpu.summary();
        let mem_summary = self.trace.mem.summary();
        let mut group_finish: Vec<(String, f64)> = self
            .group_finish
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        group_finish.sort_by(|a, b| a.0.cmp(&b.0));
        let mut completions = Vec::new();
        let mut slowdowns = Vec::new();
        for j in &self.jobs {
            if let Some(done) = j.finished_at {
                let ct = done - j.submitted_at;
                completions.push(ct);
                slowdowns.push(ct / j.ideal_service());
            }
        }
        let counters = self.master.engine_counters();
        let engine_shards = self.master.engine_shards();
        let obs = self.master.take_obs().map(|rec| rec.into_summary(counters, engine_shards));
        Ok(OnlineResult {
            label: format!("{}/{}", self.cfg.policy, self.cfg.mode.label()),
            makespan,
            jobs_completed: self.trace.jobs_completed(),
            mean_cpu: cpu_summary.mean,
            mean_mem: mem_summary.mean,
            std_cpu: cpu_summary.stddev,
            std_mem: mem_summary.stddev,
            group_finish,
            cycles: self.master.cycles,
            grants: self.master.total_grants,
            tasks_done: self.tasks_done,
            completion: DistStats::of(&completions),
            slowdown: DistStats::of(&slowdowns),
            obs,
            trace: self.trace,
        })
    }

    fn finished(&self) -> bool {
        self.queues.iter().all(|q| q.is_drained())
            && self.jobs.iter().all(|j| j.is_finished())
    }

    fn on_job_arrival(&mut self, queue: usize, now: f64) -> Result<()> {
        let Some(recipe) = self.queues[queue].next_job() else { return Ok(()) };
        let spec = self.queues[queue].spec.clone();
        let job_id = self.jobs.len();
        let name = format!("{}-q{}-j{}", spec.kind.label(), queue, job_id);
        let declared = match self.cfg.mode {
            AllocatorMode::Characterized => Some(spec.executor_demand),
            AllocatorMode::Oblivious => None,
        };
        // the paper's submission groups are Mesos roles: shares aggregate
        // per group (Pi = role 0, WordCount = role 1, synthetic classes
        // their own — WorkloadKind::role)
        let role = spec.kind.role();
        let weight = self.queues[queue].weight;
        match self.master.register_framework_in_role(name, declared, weight, role) {
            Ok(slot) => {
                let job = SparkJob::from_recipe(job_id, queue, slot, spec, &recipe, now);
                self.jobs.push(job);
                self.done_durations.push(Vec::new());
                self.fw_to_job.insert(slot, job_id);
                self.request_allocation();
            }
            Err(_) => {
                // all framework slots busy (releases in flight): requeue the
                // submission and retry shortly
                self.queues[queue].requeue();
                self.events.schedule_in(1.0, EventKind::JobArrival { queue });
            }
        }
        Ok(())
    }

    /// Schedule a deferred allocation cycle (Mesos' allocation-interval
    /// batching); multiple triggers within the window coalesce into one.
    fn request_allocation(&mut self) {
        if !self.alloc_pending {
            self.alloc_pending = true;
            self.events.schedule_in(self.cfg.allocation_interval, EventKind::Allocate);
        }
    }

    /// Run an allocation cycle and materialize the grants into executors.
    fn allocate(&mut self, now: f64) -> Result<()> {
        let grants = {
            let mut handler = SparkOfferHandler {
                jobs: &mut self.jobs,
                fw_to_job: &self.fw_to_job,
            };
            self.master.allocate(&mut handler, &mut self.rng)?
        };
        self.materialize(&grants, now)
    }

    fn materialize(&mut self, grants: &[Grant], now: f64) -> Result<()> {
        for g in grants {
            let job_id = *self.fw_to_job.get(&g.framework).expect("grant for unknown framework");
            let count = g.count as usize;
            let per_exec = g.amount.scaled(1.0 / g.count);
            for _ in 0..count {
                let exec_id = self.executors.len();
                let job = &mut self.jobs[job_id];
                let slots = job.spec.slots_per_executor;
                let mut exec = Executor::new(exec_id, job_id, g.agent, per_exec, slots);
                job.pending_executors = job.pending_executors.saturating_sub(1);
                job.executors.push(exec_id);
                let dispatches = fill_executor(
                    job,
                    &mut exec,
                    now,
                    self.cfg.speculation,
                    &self.done_durations[job_id],
                );
                self.executors.push(exec);
                self.schedule_dispatches(job_id, exec_id, &dispatches, now);
            }
        }
        Ok(())
    }

    fn schedule_dispatches(&mut self, job: JobId, exec: usize, ds: &[Dispatch], now: f64) {
        let _ = now;
        for d in ds {
            self.events.schedule_in(
                d.duration,
                EventKind::TaskFinish {
                    job,
                    exec,
                    task: d.task,
                    attempt: d.attempt,
                    duration: d.duration,
                },
            );
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_task_finish(
        &mut self,
        job_id: JobId,
        exec_id: usize,
        task: usize,
        attempt: u32,
        duration: f64,
        now: f64,
        compute: &mut dyn TaskCompute,
    ) -> Result<()> {
        self.executors[exec_id].vacate();
        let won = self.jobs[job_id].tasks[task].finish_attempt(attempt, now);
        if won {
            self.tasks_done += 1;
            self.done_durations[job_id].push(duration);
            let kind = self.jobs[job_id].spec.kind;
            compute.run_task(kind, (job_id as u64) << 20 | task as u64)?;
            let job_done = self.jobs[job_id].mark_task_done(task, now);
            if job_done {
                self.complete_job(job_id, now)?;
                return Ok(());
            }
        }
        // keep this executor busy if the job still has work
        if !self.jobs[job_id].is_finished() {
            let job = &mut self.jobs[job_id];
            let exec = &mut self.executors[exec_id];
            let dispatches = fill_executor(
                job,
                exec,
                now,
                self.cfg.speculation,
                &self.done_durations[job_id],
            );
            self.schedule_dispatches(job_id, exec_id, &dispatches, now);
        }
        Ok(())
    }

    fn complete_job(&mut self, job_id: JobId, now: f64) -> Result<()> {
        self.trace.job_completed(now);
        let queue = self.jobs[job_id].queue;
        let slot = self.jobs[job_id].framework;
        let kind_label = self.jobs[job_id].spec.kind.label();
        let entry = self.group_finish.entry(kind_label).or_insert(0.0);
        *entry = entry.max(now);

        // executors terminate with the job (§3.2); their resources reach the
        // allocator staggered by up to release_jitter seconds (§3.5.3)
        let exec_ids = self.jobs[job_id].executors.clone();
        for eid in exec_ids {
            let exec = &mut self.executors[eid];
            exec.terminated = true;
            let jitter = self.rng.f64() * self.cfg.release_jitter;
            self.events.schedule_in(
                jitter,
                EventKind::Release {
                    framework: slot,
                    agent: exec.agent,
                    amount: exec.demand,
                    count: 1.0,
                },
            );
        }
        self.master.finish_framework(slot);
        self.fw_to_job.remove(&slot);
        // a closed queue submits its next job right away; open queues'
        // arrivals were scheduled up front
        if self.queues[queue].closed {
            self.events.schedule(now, EventKind::JobArrival { queue });
        }
        Ok(())
    }
}

/// The Spark side of the offer protocol.
struct SparkOfferHandler<'a> {
    jobs: &'a mut Vec<SparkJob>,
    fw_to_job: &'a HashMap<usize, JobId>,
}

impl OfferHandler for SparkOfferHandler<'_> {
    fn wants(&self, framework: usize) -> bool {
        self.fw_to_job
            .get(&framework)
            .map(|j| self.jobs[*j].executors_wanted() > 0)
            .unwrap_or(false)
    }

    fn accept(&mut self, offer: &Offer) -> (f64, ResVec) {
        let Some(&job_id) = self.fw_to_job.get(&offer.framework) else {
            return (0.0, ResVec::zero(offer.resources.len()));
        };
        let job = &mut self.jobs[job_id];
        let d = job.spec.executor_demand;
        let fit = offer.executors_that_fit(&d) as usize;
        let take = fit.min(job.executors_wanted());
        if take == 0 {
            return (0.0, ResVec::zero(offer.resources.len()));
        }
        job.pending_executors += take;
        (take as f64, d.scaled(take as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(policy: &str, mode: AllocatorMode, seed: u64) -> OnlineResult {
        let mut cfg = OnlineConfig::small(policy, mode);
        cfg.seed = seed;
        OnlineSim::new(cfg).unwrap().run().unwrap()
    }

    #[test]
    fn small_run_completes_all_jobs() {
        let r = run("drf", AllocatorMode::Characterized, 1);
        assert_eq!(r.jobs_completed, 8); // 4 queues x 2 jobs
        assert!(r.makespan > 0.0);
        assert!(r.tasks_done >= 8 * 8);
        assert!(r.mean_cpu > 0.0 && r.mean_mem > 0.0);
        // per-job stats populated and sane
        assert_eq!(r.completion.n, 8);
        assert!(r.completion.p50 > 0.0 && r.completion.max >= r.completion.p50);
        assert!(r.slowdown.p50 >= 1.0 - 1e-9, "slowdown {:?}", r.slowdown);
    }

    #[test]
    fn oblivious_mode_completes_too() {
        let r = run("drf", AllocatorMode::Oblivious, 2);
        assert_eq!(r.jobs_completed, 8);
    }

    #[test]
    fn all_policies_complete_characterized() {
        for p in crate::scheduler::POLICY_NAMES {
            let r = run(p, AllocatorMode::Characterized, 3);
            assert_eq!(r.jobs_completed, 8, "{p}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run("psdsf", AllocatorMode::Characterized, 42);
        let b = run("psdsf", AllocatorMode::Characterized, 42);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.grants, b.grants);
        assert_eq!(a.trace.cpu.values(), b.trace.cpu.values());
    }

    #[test]
    fn seeds_change_trajectories() {
        let a = run("drf", AllocatorMode::Characterized, 1);
        let b = run("drf", AllocatorMode::Characterized, 2);
        assert!(a.makespan != b.makespan || a.trace.cpu.values() != b.trace.cpu.values());
    }

    #[test]
    fn staged_registration_runs() {
        let mut cfg = OnlineConfig::paper_staged("rpsdsf", 1);
        for q in &mut cfg.queues {
            q.workload.tasks_per_job = 6;
            q.workload.max_executors = 3;
        }
        cfg.queues.truncate(4);
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 4);
    }

    #[test]
    fn utilization_bounded() {
        let r = run("rpsdsf", AllocatorMode::Characterized, 7);
        for &v in r.trace.cpu.values() {
            assert!((0.0..=1.0 + 1e-9).contains(&v));
        }
        for &v in r.trace.mem.values() {
            assert!((0.0..=1.0 + 1e-9).contains(&v));
        }
    }

    #[test]
    fn open_arrivals_complete_and_respect_times() {
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        for q in &mut cfg.queues {
            q.arrival = ArrivalProcess::Poisson { rate: 0.05 };
        }
        cfg.seed = 13;
        let scenario = realize(&cfg, "test-open");
        let first_arrival = scenario
            .queues
            .iter()
            .flat_map(|q| q.arrivals.iter().copied())
            .fold(f64::INFINITY, f64::min);
        let r = OnlineSim::with_scenario(cfg, scenario).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8);
        // nothing can finish before the first arrival
        assert!(r.makespan > first_arrival);
    }

    #[test]
    fn scripted_churn_drains_and_rejoins() {
        let mut cfg = OnlineConfig::small("rpsdsf", AllocatorMode::Characterized);
        cfg.seed = 17;
        // take two agents out for a mid-run window
        cfg.churn = ChurnModel::Scripted(vec![
            ChurnEvent { t: 10.0, agent: 4, up: false },
            ChurnEvent { t: 10.0, agent: 5, up: false },
            ChurnEvent { t: 90.0, agent: 4, up: true },
            ChurnEvent { t: 90.0, agent: 5, up: true },
        ]);
        let r = OnlineSim::new(cfg.clone()).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8, "churn must not lose jobs");
        // the outage genuinely alters the run (2 of 6 agents gone for most
        // of it) but the workload itself is identical (same seed streams)
        cfg.churn = ChurnModel::None;
        let base = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(base.jobs_completed, 8);
        assert!(
            base.makespan != r.makespan || base.trace.cpu.values() != r.trace.cpu.values(),
            "an 80s outage of a third of the cluster left no trace"
        );
    }

    #[test]
    fn queue_weight_reaches_framework_registration() {
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        cfg.queues[0].weight = 2.0;
        let scenario = realize(&cfg, "weighted");
        assert_eq!(scenario.queues[0].weight, 2.0, "realize must carry the queue weight");
        assert_eq!(scenario.queues[1].weight, 1.0);
        let mut sim = OnlineSim::with_scenario(cfg, scenario).unwrap();
        sim.on_job_arrival(0, 0.0).unwrap();
        sim.on_job_arrival(1, 0.0).unwrap();
        assert_eq!(sim.master.state.framework(0).weight, 2.0);
        assert_eq!(sim.master.state.framework(1).weight, 1.0);
    }

    #[test]
    fn weighted_run_still_completes() {
        let mut cfg = OnlineConfig::small("psdsf", AllocatorMode::Characterized);
        cfg.queues[0].weight = 2.0;
        cfg.seed = 11;
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8);
    }

    #[test]
    fn scenario_dim_mismatch_rejected() {
        let cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        let mut wrong_agents = realize(&cfg, "x");
        wrong_agents.agents = 3;
        assert!(OnlineSim::with_scenario(cfg.clone(), wrong_agents).is_err());
        let mut wrong_kinds = realize(&cfg, "x");
        wrong_kinds.kinds = 3;
        assert!(OnlineSim::with_scenario(cfg, wrong_kinds).is_err());
    }

    #[test]
    fn sharded_run_bit_identical_to_serial() {
        let mut serial = OnlineConfig::small("rpsdsf", AllocatorMode::Characterized);
        serial.seed = 21;
        let mut sharded = serial.clone();
        sharded.shards = 4;
        let a = OnlineSim::new(serial).unwrap().run().unwrap();
        let b = OnlineSim::new(sharded).unwrap().run().unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.grants, b.grants);
        assert_eq!(a.trace.cpu.values(), b.trace.cpu.values());
        assert_eq!(a.trace.mem.values(), b.trace.mem.values());
    }

    #[test]
    fn obs_run_matches_silent_run_and_summarizes() {
        let mut cfg = OnlineConfig::small("psdsf", AllocatorMode::Characterized);
        cfg.seed = 29;
        let silent = OnlineSim::new(cfg.clone()).unwrap().run().unwrap();
        assert!(silent.obs.is_none(), "no recorder unless asked");
        cfg.obs = true;
        let traced = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(silent.makespan, traced.makespan, "tracing changed the run");
        assert_eq!(silent.grants, traced.grants);
        assert_eq!(silent.trace.cpu.values(), traced.trace.cpu.values());
        let s = traced.obs.expect("summary attached");
        assert!(s.cycles > 0);
        assert!(!s.events.is_empty());
        assert_eq!(s.dropped, 0, "small run fits the ring");
        assert!(s.counters.full_rescores > 0);
        // every phase present in the histogram table
        assert_eq!(s.phases.len(), crate::obs::ObsPhase::ALL.len());
    }

    #[test]
    fn churn_scenario_from_registry_completes() {
        let cfg = crate::workload::scenario::scenario_config(
            "churn",
            "drf",
            AllocatorMode::Characterized,
            Some(1),
            23,
        )
        .unwrap();
        let expected: usize = cfg.queues.iter().map(|q| q.jobs).sum();
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, expected);
    }
}

//! The online experiment: Spark jobs on a Mesos-like cluster — the
//! machinery behind Figures 3–9, generalized to scenario workloads.
//!
//! Wiring: submission queues register frameworks with the [`Master`]; the
//! allocator grants executors (fine- or coarse-grained per
//! [`AllocatorMode`]); executors pull microtasks from their job's driver;
//! task finishes free slots and eventually complete jobs, whose executor
//! resources are released back (possibly staggered — §3.5.3) and trigger
//! new allocation cycles; a sampler records the allocated CPU/mem fractions
//! the figures plot.
//!
//! The workload side is a [`WorkloadStream`]
//! ([`crate::workload::stream`]): closed queues pull their next job from
//! the stream on completion (the paper's batches), open queues keep
//! exactly one scheduled arrival per queue in the event horizon and pull
//! the following one when it fires (bounded lookahead), agents churn per
//! the realized schedule, and every task duration is fixed by the stream —
//! so the same scenario, recorded and replayed, drives any scheduler
//! identically. Eager [`RealizedScenario`]s still work through the
//! [`WorkloadStream::from_realized`] adapter.
//!
//! Million-job scale is why the simulator is memory-bounded end to end:
//! job and executor state live in free-list slabs that retire once a job's
//! last in-flight task event fires (losing speculative attempts finish
//! after completion, hence the per-job in-flight refcount), and per-job
//! completion/slowdown metrics spill into streaming quantile estimators
//! ([`StreamingDist`]) past a threshold instead of holding every sample.

use crate::cluster::{ReleaseMode, ServerType};
use crate::error::{Error, Result};
use crate::mesos::allocator::{AllocatorMode, Grant};
use crate::mesos::master::Master;
use crate::mesos::offer::Offer;
use crate::mesos::OfferHandler;
use crate::metrics::{DistStats, StreamingDist};
use crate::obs::ObsSummary;
use crate::resources::ResVec;
use crate::rng::Rng;
use crate::scheduler::{
    policy_by_name, KernelKind, NativeScorer, PreemptCandidate, PreemptPolicy, Scorer,
};
use crate::sim::engine::EventQueue;
use crate::sim::events::{EventKind, JobId};
use crate::sim::trace::TraceRecorder;
use crate::spark::driver::{fill_executor, Dispatch, SpeculationCfg};
use crate::spark::executor::Executor;
use crate::spark::job::{JobClass, SparkJob};
use crate::spark::queue::SubmissionQueue;
use crate::spark::workload::{WorkloadKind, WorkloadSpec};
use crate::workload::arrival::ArrivalProcess;
use crate::workload::churn::{ChurnEvent, ChurnModel};
use crate::workload::import::ImportSpec;
use crate::workload::scenario::RealizedScenario;
use crate::workload::stream::{Demux, WorkloadStream};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

/// One submission queue's configuration.
#[derive(Debug, Clone)]
pub struct QueueSpec {
    pub workload: WorkloadSpec,
    pub jobs: usize,
    /// How this queue's jobs arrive (closed batch by default).
    pub arrival: ArrivalProcess,
    /// Fair-share weight φ of this queue's frameworks (the paper uses 1).
    /// Threaded through `Master::register_framework` and recorded in the
    /// scenario trace, so weighted runs replay exactly.
    pub weight: f64,
    /// Deadline/priority class stamped on every job this queue submits
    /// (best-effort by default — no deadline, priority 0).
    pub class: JobClass,
}

impl QueueSpec {
    /// A closed-loop batch queue (the paper's behaviour).
    pub fn closed(workload: WorkloadSpec, jobs: usize) -> Self {
        QueueSpec {
            workload,
            jobs,
            arrival: ArrivalProcess::Closed,
            weight: 1.0,
            class: JobClass::default(),
        }
    }

    /// An open queue whose jobs arrive per `arrival`.
    pub fn open(workload: WorkloadSpec, jobs: usize, arrival: ArrivalProcess) -> Self {
        QueueSpec { workload, jobs, arrival, weight: 1.0, class: JobClass::default() }
    }

    /// Builder-style fair-share weight override.
    pub fn weighted(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Builder-style deadline/priority class override.
    pub fn with_class(mut self, class: JobClass) -> Self {
        self.class = class;
        self
    }
}

/// Full configuration of an online run.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    pub cluster: Vec<ServerType>,
    /// Register agents one-by-one (Fig 9) instead of all up-front.
    pub staged: bool,
    /// Seconds between staged registrations.
    pub stage_interval: f64,
    pub queues: Vec<QueueSpec>,
    /// Scheduler registry name ("drf", "psdsf", …).
    pub policy: String,
    pub mode: AllocatorMode,
    pub seed: u64,
    /// Utilization sampling period (seconds).
    pub sample_dt: f64,
    /// Max staggering of per-executor releases after job completion.
    pub release_jitter: f64,
    /// Mesos' allocation batching interval (`--allocation_interval`):
    /// state changes schedule one deferred allocation cycle this many
    /// seconds later, pooling a completing job's releases.
    pub allocation_interval: f64,
    /// §3.1: released agents handled as a *pool* (batched cycle, agent
    /// selection matters — default) or *sequentially* (each release triggers
    /// its own immediate cycle, so the freed agent is effectively the only
    /// candidate).
    pub release_mode: ReleaseMode,
    pub speculation: SpeculationCfg,
    /// Cluster churn model (realized into a schedule at scenario time).
    pub churn: ChurnModel,
    /// Kill-based preemption (`--preempt priority|share`): when a
    /// deadline-class job is starved of executors, revoke one executor of a
    /// strictly-lower-priority job per allocation cycle. `None` (default)
    /// never preempts — runs are bit-identical to the pre-SLO simulator.
    pub preempt: Option<PreemptPolicy>,
    /// Parallel scoring/argmin shards for the native engine (1 = serial;
    /// results are bit-identical at any count).
    pub shards: usize,
    /// Row-fill kernel for the native engine (`--kernel scalar|batched`;
    /// results are bit-identical either way).
    pub kernel: KernelKind,
    /// Attach the obs flight recorder (CLI `--obs`): decision traces and
    /// cycle-phase timings land in [`OnlineResult::obs`]. Grants are
    /// bit-identical with or without it.
    pub obs: bool,
    /// Per-series sample count above which completion/slowdown metrics
    /// spill from exact buffers into P² streaming quantile estimators
    /// (`--stats-threshold`; million-job runs keep O(1) metrics memory).
    pub stats_threshold: usize,
    /// Drive the run from a production trace instead of `queues`
    /// (`--trace-import FILE --trace-format google|alibaba`). The queue
    /// set then comes from the trace's tenant classes.
    pub import: Option<ImportSpec>,
    /// Safety cutoff (simulated seconds).
    pub max_sim_time: f64,
}

impl OnlineConfig {
    /// The paper's §3.3 set-up: 6 heterogeneous agents, two groups × five
    /// queues × `jobs_per_queue` jobs.
    pub fn paper(policy: &str, mode: AllocatorMode, jobs_per_queue: usize) -> Self {
        let mut queues = Vec::new();
        for _ in 0..5 {
            queues.push(QueueSpec::closed(WorkloadSpec::pi(), jobs_per_queue));
        }
        for _ in 0..5 {
            queues.push(QueueSpec::closed(WorkloadSpec::wordcount(), jobs_per_queue));
        }
        OnlineConfig {
            cluster: ServerType::paper_heterogeneous(),
            staged: false,
            stage_interval: 60.0,
            queues,
            policy: policy.to_string(),
            mode,
            seed: 0x5EED,
            sample_dt: 5.0,
            release_jitter: 0.5,
            allocation_interval: 1.0,
            release_mode: ReleaseMode::Pool,
            speculation: SpeculationCfg::default(),
            churn: ChurnModel::None,
            preempt: None,
            shards: 1,
            kernel: KernelKind::default(),
            obs: false,
            stats_threshold: StreamingDist::DEFAULT_THRESHOLD,
            import: None,
            max_sim_time: 1e7,
        }
    }

    /// §3.6's homogeneous cluster variant.
    pub fn paper_homogeneous(policy: &str, mode: AllocatorMode, jobs_per_queue: usize) -> Self {
        let mut cfg = OnlineConfig::paper(policy, mode, jobs_per_queue);
        cfg.cluster = ServerType::paper_homogeneous();
        cfg
    }

    /// §3.7 / Fig 9: three agents (one per type) registered one by one,
    /// 5 queues × 20 jobs per group.
    pub fn paper_staged(policy: &str, jobs_per_queue: usize) -> Self {
        let mut cfg = OnlineConfig::paper(policy, AllocatorMode::Characterized, jobs_per_queue);
        cfg.cluster = ServerType::paper_staged();
        cfg.staged = true;
        cfg
    }

    /// The scale scenario family unlocked by the dynamic-dimension scoring
    /// core: `agents` heterogeneous servers ([`ServerType::scaled`]) driven
    /// by `queues` concurrent submission queues (alternating Pi/WordCount,
    /// one in-flight job each — so `queues` concurrent frameworks) of
    /// `jobs_per_queue` jobs. `scaled("rpsdsf", mode, 64, 128, 1)` runs a
    /// 64-agent / 128-framework experiment end-to-end; the paper's own
    /// configurations are the `paper*` constructors above.
    pub fn scaled(
        policy: &str,
        mode: AllocatorMode,
        agents: usize,
        queues: usize,
        jobs_per_queue: usize,
    ) -> Self {
        let mut cfg = OnlineConfig::paper(policy, mode, jobs_per_queue);
        cfg.cluster = ServerType::scaled(agents);
        cfg.queues = (0..queues)
            .map(|q| {
                let mut w = if q % 2 == 0 { WorkloadSpec::pi() } else { WorkloadSpec::wordcount() };
                // keep per-job work small: the point is breadth, not depth
                w.tasks_per_job = 8;
                w.max_executors = 2;
                QueueSpec::closed(w, jobs_per_queue)
            })
            .collect();
        cfg
    }

    /// Resolve `--shards auto` / `shards = "auto"`: the detected core
    /// count ([`std::thread::available_parallelism`]), clamped to the
    /// persistent scoring pool's bounds. Config front-ends resolve the
    /// string form through here at parse time, so [`OnlineConfig::shards`]
    /// is always a concrete count.
    pub fn auto_shards() -> usize {
        crate::scheduler::pool::auto_shards()
    }

    /// A small fast configuration for tests.
    pub fn small(policy: &str, mode: AllocatorMode) -> Self {
        let mut cfg = OnlineConfig::paper(policy, mode, 2);
        for q in &mut cfg.queues {
            q.workload.tasks_per_job = 8;
            q.workload.max_executors = 4;
        }
        cfg.queues.truncate(4); // 2 Pi + … keep two of each group
        cfg.queues.remove(2);
        cfg.queues.push(QueueSpec::closed(
            {
                let mut w = WorkloadSpec::wordcount();
                w.tasks_per_job = 8;
                w.max_executors = 4;
                w
            },
            2,
        ));
        cfg
    }
}

/// Hook for running real task compute through the PJRT runtime (the e2e
/// example); the figure sweeps use [`NoCompute`].
pub trait TaskCompute {
    /// Execute the body of one finished task attempt.
    fn run_task(&mut self, kind: WorkloadKind, seed: u64) -> Result<()>;
}

/// Default no-op compute.
pub struct NoCompute;

impl TaskCompute for NoCompute {
    fn run_task(&mut self, _kind: WorkloadKind, _seed: u64) -> Result<()> {
        Ok(())
    }
}

/// Workload-stream counters of one run (obs: jobs streamed, realized
/// lookahead depth, importer parse errors, slab high-water marks).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StreamStats {
    /// Jobs pulled from the workload stream.
    pub jobs_streamed: u64,
    /// Peak number of jobs buffered between the stream and the simulator
    /// (queue retry/arrival buffers plus the trace demux).
    pub max_lookahead: usize,
    /// Importer rows skipped or repaired (0 for synthetic streams).
    pub parse_errors: u64,
    /// Peak concurrently-live jobs (slab occupancy high-water mark).
    pub peak_active_jobs: usize,
    /// Peak concurrently-live executors.
    pub peak_live_executors: usize,
}

/// Aggregated outcome of one online run.
#[derive(Debug, Clone)]
pub struct OnlineResult {
    pub label: String,
    /// Time the last job finished.
    pub makespan: f64,
    pub jobs_completed: usize,
    pub trace: TraceRecorder,
    pub mean_cpu: f64,
    pub mean_mem: f64,
    pub std_cpu: f64,
    pub std_mem: f64,
    /// Last finish time per submission group.
    pub group_finish: Vec<(String, f64)>,
    /// Allocator cycles run / grants issued (perf accounting).
    pub cycles: u64,
    pub grants: u64,
    /// Tasks executed (incl. speculative winners only).
    pub tasks_done: usize,
    /// Per-job completion time (finish − submission) distribution.
    pub completion: DistStats,
    /// Per-job slowdown (completion / inherent service) distribution.
    pub slowdown: DistStats,
    /// Per-queue-class slowdown distributions (SLO percentiles per tenant
    /// class — workload kind for synthetic scenarios, tenant tag for
    /// imported traces), sorted by class name.
    pub class_slowdown: Vec<(String, DistStats)>,
    /// Tardiness (`max(0, completion − deadline)`) over deadline-class
    /// jobs; `n == 0` when the workload has no deadlines.
    pub tardiness: DistStats,
    /// Deadline-class jobs completed / of those, completed past deadline.
    pub deadline_jobs: usize,
    pub deadline_misses: usize,
    /// Executors lost without drain (agent kills + preemption), and the
    /// subset evicted by the preemption hook.
    pub revocations: u64,
    pub preemptions: u64,
    /// Tasks whose sole in-flight attempt died with a revoked executor and
    /// were re-queued for a speculative re-draw.
    pub reattempts: u64,
    /// Workload-stream counters (jobs streamed, lookahead, parse errors).
    pub stream: StreamStats,
    /// Flight-recorder output ([`OnlineConfig::obs`]): decision events,
    /// per-phase timing histograms and engine counters.
    pub obs: Option<ObsSummary>,
}

/// The online simulator.
pub struct OnlineSim {
    cfg: OnlineConfig,
    master: Master,
    events: EventQueue,
    rng: Rng,
    queues: Vec<SubmissionQueue>,
    churn: Vec<ChurnEvent>,
    /// Job slab: slots retire (and recycle through `free_jobs`) once a
    /// job's last in-flight task event has fired.
    jobs: Vec<Option<SparkJob>>,
    free_jobs: Vec<usize>,
    /// Outstanding TaskFinish events per job slot — a job retires only at
    /// zero, since losing speculative attempts fire after completion.
    inflight: Vec<u32>,
    /// Executor slab, recycled with its job.
    executors: Vec<Option<Executor>>,
    free_execs: Vec<usize>,
    /// Revocation epoch per executor *slot*, bumped when the slot's
    /// occupant is killed. A [`EventKind::TaskFinish`] whose stamped epoch
    /// mismatches is stale (its executor died mid-flight) and is dropped —
    /// the guard that makes abrupt loss safe against slab recycling.
    exec_epoch: Vec<u32>,
    fw_to_job: HashMap<usize, JobId>,
    done_durations: Vec<Vec<f64>>,
    trace: TraceRecorder,
    group_finish: HashMap<&'static str, f64>,
    tasks_done: usize,
    /// An Allocate event is already queued (coalesces triggers).
    alloc_pending: bool,
    /// Monotonic submission counter (job display names survive slot reuse).
    job_seq: usize,
    /// Jobs submitted but not yet completed.
    active_jobs: usize,
    live_execs: usize,
    makespan: f64,
    completion: StreamingDist,
    slowdown: StreamingDist,
    class_slowdown: BTreeMap<String, StreamingDist>,
    /// SLO accounting over deadline-class jobs.
    tardiness: StreamingDist,
    deadline_jobs: usize,
    deadline_misses: usize,
    revocations: u64,
    preemptions: u64,
    reattempts: u64,
    /// Current / peak jobs buffered between stream and simulator.
    lookahead_now: usize,
    peak_lookahead: usize,
    peak_active_jobs: usize,
    peak_live_execs: usize,
    /// Shared demux of file/import streams (lookahead + parse counters).
    demux: Option<Rc<RefCell<Demux>>>,
}

impl OnlineSim {
    pub fn new(cfg: OnlineConfig) -> Result<Self> {
        Self::with_scorer(cfg, Box::new(NativeScorer::new()))
    }

    /// Build with an explicit scoring backend (`--scorer hlo` uses the
    /// PJRT-backed one). Streams the configured workload live.
    pub fn with_scorer(cfg: OnlineConfig, scorer: Box<dyn Scorer>) -> Result<Self> {
        let stream = WorkloadStream::sampled(&cfg, "adhoc");
        Self::with_stream_scorer(cfg, stream, scorer)
    }

    /// Build from an eagerly realized scenario (v2 trace replay, tests).
    pub fn with_scenario(cfg: OnlineConfig, scenario: RealizedScenario) -> Result<Self> {
        Self::with_scenario_scorer(cfg, scenario, Box::new(NativeScorer::new()))
    }

    /// Build from a realized scenario and an explicit scoring backend —
    /// a thin adapter over the streaming constructor.
    pub fn with_scenario_scorer(
        cfg: OnlineConfig,
        scenario: RealizedScenario,
        scorer: Box<dyn Scorer>,
    ) -> Result<Self> {
        Self::with_stream_scorer(cfg, WorkloadStream::from_realized(scenario), scorer)
    }

    /// Build from a workload stream.
    pub fn with_stream(cfg: OnlineConfig, stream: WorkloadStream) -> Result<Self> {
        Self::with_stream_scorer(cfg, stream, Box::new(NativeScorer::new()))
    }

    /// Build from a workload stream and an explicit scoring backend — the
    /// core constructor every other one funnels into.
    pub fn with_stream_scorer(
        cfg: OnlineConfig,
        stream: WorkloadStream,
        scorer: Box<dyn Scorer>,
    ) -> Result<Self> {
        // imported streams define their own queue set; otherwise the
        // stream must line up with the configured queues
        if !stream.imported && stream.queues.len() != cfg.queues.len() {
            return Err(Error::Config(format!(
                "scenario has {} queues but the configuration has {}",
                stream.queues.len(),
                cfg.queues.len()
            )));
        }
        if let Some(bad) = stream.churn.iter().find(|e| e.agent >= cfg.cluster.len()) {
            return Err(Error::Config(format!(
                "scenario churn references agent {} but the cluster has {} agents",
                bad.agent,
                cfg.cluster.len()
            )));
        }
        if stream.agents != cfg.cluster.len() {
            return Err(Error::Config(format!(
                "scenario was realized for {} agents but the configuration has {} — \
                 refusing to replay against a different cluster",
                stream.agents,
                cfg.cluster.len()
            )));
        }
        let kinds = cfg.cluster.first().map(|s| s.capacity.len()).unwrap_or(2);
        if stream.kinds != kinds {
            return Err(Error::Config(format!(
                "scenario was realized with {} resource kinds but the cluster has {kinds}",
                stream.kinds
            )));
        }
        if let Some(bad) =
            stream.queues.iter().find(|q| q.meta.spec.executor_demand.len() != kinds)
        {
            return Err(Error::Config(format!(
                "scenario workload '{}' has {} resource dims but the cluster has {kinds}",
                bad.meta.spec.kind.label(),
                bad.meta.spec.executor_demand.len()
            )));
        }
        let policy = policy_by_name(&cfg.policy)?;
        let pool = if cfg.staged {
            crate::cluster::AgentPool::new_staged(&cfg.cluster)
        } else {
            crate::cluster::AgentPool::new(&cfg.cluster)
        };
        let mut master = Master::new(pool, policy, cfg.mode, scorer);
        master.set_shards(cfg.shards.max(1));
        master.set_kernel(cfg.kernel);
        if cfg.obs {
            master.enable_obs(crate::obs::DEFAULT_EVENT_CAPACITY);
        }
        let label = format!("{}/{}", cfg.policy, cfg.mode.label());
        let demux = stream.demux.clone();
        let churn = stream.churn;
        let queues: Vec<SubmissionQueue> = stream
            .queues
            .into_iter()
            .enumerate()
            .map(|(i, qs)| SubmissionQueue::new(i, qs.meta, qs.source))
            .collect();
        let rng = Rng::new(cfg.seed);
        let stats_threshold = cfg.stats_threshold;
        Ok(OnlineSim {
            master,
            events: EventQueue::new(),
            rng,
            queues,
            churn,
            jobs: Vec::new(),
            free_jobs: Vec::new(),
            inflight: Vec::new(),
            executors: Vec::new(),
            free_execs: Vec::new(),
            exec_epoch: Vec::new(),
            fw_to_job: HashMap::new(),
            done_durations: Vec::new(),
            trace: TraceRecorder::new(&label),
            group_finish: HashMap::new(),
            tasks_done: 0,
            alloc_pending: false,
            job_seq: 0,
            active_jobs: 0,
            live_execs: 0,
            makespan: 0.0,
            completion: StreamingDist::with_threshold(stats_threshold),
            slowdown: StreamingDist::with_threshold(stats_threshold),
            class_slowdown: BTreeMap::new(),
            tardiness: StreamingDist::with_threshold(stats_threshold),
            deadline_jobs: 0,
            deadline_misses: 0,
            revocations: 0,
            preemptions: 0,
            reattempts: 0,
            lookahead_now: 0,
            peak_lookahead: 0,
            peak_active_jobs: 0,
            peak_live_execs: 0,
            demux,
            cfg,
        })
    }

    /// Override the oblivious demand-inference rule (ablation bench).
    pub fn set_inference_rule(&mut self, rule: crate::mesos::framework::InferenceRule) {
        self.master.set_inference_rule(rule);
    }

    /// Run to completion with no real compute.
    pub fn run(self) -> Result<OnlineResult> {
        let mut none = NoCompute;
        self.run_with_compute(&mut none)
    }

    /// Run to completion, invoking `compute` for every winning task attempt.
    pub fn run_with_compute(mut self, compute: &mut dyn TaskCompute) -> Result<OnlineResult> {
        // bootstrap: agents, churn, submissions, sampler
        if self.cfg.staged {
            for (k, _) in self.cfg.cluster.iter().enumerate() {
                self.events
                    .schedule(k as f64 * self.cfg.stage_interval, EventKind::AgentUp { agent: k });
            }
        }
        for ev in &self.churn {
            let kind = if ev.up {
                EventKind::AgentUp { agent: ev.agent }
            } else if ev.kill {
                EventKind::AgentKilled { agent: ev.agent }
            } else {
                EventKind::AgentDown { agent: ev.agent }
            };
            self.events.schedule(ev.t, kind);
        }
        for q in 0..self.queues.len() {
            if self.queues[q].closed {
                self.events.schedule(0.0, EventKind::JobArrival { queue: q });
            } else {
                // bounded lookahead: only the next arrival per queue lives in
                // the event horizon; each JobArrival pulls its successor
                if let Some(t) = self.queues[q].schedule_next()? {
                    self.events.schedule(t, EventKind::JobArrival { queue: q });
                }
            }
        }
        self.note_lookahead();
        self.events.schedule(0.0, EventKind::Sample);

        while let Some(ev) = self.events.pop() {
            if ev.time > self.cfg.max_sim_time {
                break;
            }
            let now = ev.time;
            match ev.kind {
                EventKind::AgentUp { agent } => {
                    self.master.agent_up(agent);
                    self.request_allocation();
                }
                EventKind::AgentDown { agent } => {
                    self.master.agent_down(agent);
                }
                EventKind::AgentKilled { agent } => {
                    self.on_agent_killed(agent)?;
                }
                EventKind::ExecutorRevoked { job, exec } => {
                    // stale if the slot moved on since the eviction was
                    // scheduled (its job finished in the same instant)
                    let live = self.executors[exec]
                        .as_ref()
                        .is_some_and(|e| e.job == job && !e.terminated);
                    if live {
                        self.revoke_executor(exec)?;
                        self.request_allocation();
                    }
                }
                EventKind::JobArrival { queue } => self.on_job_arrival(queue, now, false)?,
                EventKind::JobRetry { queue } => self.on_job_arrival(queue, now, true)?,
                EventKind::Allocate => {
                    self.alloc_pending = false;
                    self.allocate(now)?;
                }
                EventKind::TaskFinish { job, exec, task, attempt, duration, epoch } => {
                    // epoch guard: the attempt's executor was revoked after
                    // dispatch — the work is lost, the event is stale
                    if epoch == self.exec_epoch[exec] {
                        self.on_task_finish(job, exec, task, attempt, duration, now, compute)?;
                    }
                }
                EventKind::Release { framework, agent, amount, count } => {
                    self.master.release(framework, agent, &amount, count)?;
                    match self.cfg.release_mode {
                        ReleaseMode::Pool => self.request_allocation(),
                        // sequential: the allocator reacts to each release
                        // immediately, before the rest of the job's
                        // executors free up
                        ReleaseMode::Sequential => self.allocate(now)?,
                    }
                }
                EventKind::Sample => {
                    self.trace.sample(now, &self.master.state.pool);
                    if !self.finished() {
                        self.events.schedule_in(self.cfg.sample_dt, EventKind::Sample);
                    }
                }
            }
            if self.finished() && self.events.is_empty() {
                break;
            }
        }
        // final sample after the last (possibly jittered) releases drained,
        // so traces end at zero utilization
        let t_end = self.events.now();
        self.trace.sample(t_end, &self.master.state.pool);

        let cpu_summary = self.trace.cpu.summary();
        let mem_summary = self.trace.mem.summary();
        let mut group_finish: Vec<(String, f64)> = self
            .group_finish
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        group_finish.sort_by(|a, b| a.0.cmp(&b.0));
        let (demux_lookahead, parse_errors) = match &self.demux {
            Some(d) => {
                let d = d.borrow();
                (d.max_buffered, d.parse_errors())
            }
            None => (0, 0),
        };
        let stream = StreamStats {
            jobs_streamed: self.queues.iter().map(|q| q.pulled() as u64).sum(),
            max_lookahead: self.peak_lookahead.max(demux_lookahead),
            parse_errors,
            peak_active_jobs: self.peak_active_jobs,
            peak_live_executors: self.peak_live_execs,
        };
        let class_slowdown: Vec<(String, DistStats)> = self
            .class_slowdown
            .into_iter()
            .map(|(k, v)| (k, v.finish()))
            .collect();
        let counters = self.master.engine_counters();
        let engine_shards = self.master.engine_shards();
        let obs = self.master.take_obs().map(|rec| rec.into_summary(counters, engine_shards));
        Ok(OnlineResult {
            label: format!("{}/{}", self.cfg.policy, self.cfg.mode.label()),
            makespan: self.makespan,
            jobs_completed: self.trace.jobs_completed(),
            mean_cpu: cpu_summary.mean,
            mean_mem: mem_summary.mean,
            std_cpu: cpu_summary.stddev,
            std_mem: mem_summary.stddev,
            group_finish,
            cycles: self.master.cycles,
            grants: self.master.total_grants,
            tasks_done: self.tasks_done,
            completion: self.completion.finish(),
            slowdown: self.slowdown.finish(),
            class_slowdown,
            tardiness: self.tardiness.finish(),
            deadline_jobs: self.deadline_jobs,
            deadline_misses: self.deadline_misses,
            revocations: self.revocations,
            preemptions: self.preemptions,
            reattempts: self.reattempts,
            stream,
            obs,
            trace: self.trace,
        })
    }

    fn finished(&self) -> bool {
        self.active_jobs == 0 && self.queues.iter().all(|q| q.is_drained())
    }

    /// Track the peak number of jobs buffered between sources and the sim.
    fn note_lookahead(&mut self) {
        self.lookahead_now = self.queues.iter().map(|q| q.buffered()).sum();
        if self.lookahead_now > self.peak_lookahead {
            self.peak_lookahead = self.lookahead_now;
        }
    }

    fn on_job_arrival(&mut self, queue: usize, now: f64, is_retry: bool) -> Result<()> {
        let Some(recipe) = self.queues[queue].next_job()? else { return Ok(()) };
        // a fresh arrival on an open queue pulls its successor into the
        // event horizon; retries must NOT advance the stream
        if !is_retry && !self.queues[queue].closed {
            if let Some(t) = self.queues[queue].schedule_next()? {
                self.events.schedule(t, EventKind::JobArrival { queue });
            }
        }
        self.note_lookahead();
        let spec = self.queues[queue].spec.clone();
        let job_id = match self.free_jobs.pop() {
            Some(slot) => slot,
            None => {
                self.jobs.push(None);
                self.done_durations.push(Vec::new());
                self.inflight.push(0);
                self.jobs.len() - 1
            }
        };
        let name = format!("{}-q{}-j{}", spec.kind.label(), queue, self.job_seq);
        self.job_seq += 1;
        let declared = match self.cfg.mode {
            AllocatorMode::Characterized => Some(spec.executor_demand),
            AllocatorMode::Oblivious => None,
        };
        // the paper's submission groups are Mesos roles: shares aggregate
        // per group (Pi = role 0, WordCount = role 1, synthetic classes and
        // imported tenants their own — queue metadata decides)
        let role = self.queues[queue].role;
        let weight = self.queues[queue].weight;
        match self.master.register_framework_in_role(name, declared, weight, role) {
            Ok(slot) => {
                let mut job = SparkJob::from_recipe(job_id, queue, slot, spec, &recipe, now);
                job.class = self.queues[queue].job_class;
                self.jobs[job_id] = Some(job);
                self.done_durations[job_id].clear();
                self.inflight[job_id] = 0;
                self.active_jobs += 1;
                if self.active_jobs > self.peak_active_jobs {
                    self.peak_active_jobs = self.active_jobs;
                }
                self.fw_to_job.insert(slot, job_id);
                self.request_allocation();
            }
            Err(_) => {
                // all framework slots busy (releases in flight): requeue the
                // submission and retry shortly
                self.free_jobs.push(job_id);
                self.queues[queue].requeue(recipe);
                self.events.schedule_in(1.0, EventKind::JobRetry { queue });
            }
        }
        Ok(())
    }

    /// Schedule a deferred allocation cycle (Mesos' allocation-interval
    /// batching); multiple triggers within the window coalesce into one.
    fn request_allocation(&mut self) {
        if !self.alloc_pending {
            self.alloc_pending = true;
            self.events.schedule_in(self.cfg.allocation_interval, EventKind::Allocate);
        }
    }

    /// Run an allocation cycle and materialize the grants into executors.
    fn allocate(&mut self, now: f64) -> Result<()> {
        let grants = {
            let mut handler = SparkOfferHandler {
                jobs: &mut self.jobs,
                fw_to_job: &self.fw_to_job,
            };
            self.master.allocate(&mut handler, &mut self.rng)?
        };
        self.materialize(&grants, now)?;
        if self.cfg.preempt.is_some() {
            self.maybe_preempt(now);
        }
        Ok(())
    }

    /// Abrupt agent loss: deregister the agent and revoke every live
    /// executor on it *without* drain — in-flight attempts are lost and
    /// sole-attempt tasks re-queued. Already-terminated executors keep
    /// their scheduled [`EventKind::Release`] (kill after completion must
    /// not double-release).
    fn on_agent_killed(&mut self, agent: usize) -> Result<()> {
        self.master.agent_killed(agent);
        let victims: Vec<usize> = self
            .executors
            .iter()
            .enumerate()
            .filter(|(_, e)| e.as_ref().is_some_and(|e| e.agent == agent && !e.terminated))
            .map(|(i, _)| i)
            .collect();
        for eid in victims {
            self.revoke_executor(eid)?;
        }
        self.request_allocation();
        Ok(())
    }

    /// Kill one live executor: drop its in-flight attempts (their
    /// [`EventKind::TaskFinish`] events go stale via the slot's bumped
    /// epoch), re-queue tasks whose only attempt died, release the
    /// reservation, and recycle the slot. No scheduler-RNG draws — kill
    /// paths stay off the common-random-numbers streams.
    fn revoke_executor(&mut self, eid: usize) -> Result<()> {
        let exec = self.executors[eid].take().expect("revoke on empty executor slot");
        debug_assert!(!exec.terminated, "revoking a terminated executor double-releases");
        let job_id = exec.job;
        self.exec_epoch[eid] = self.exec_epoch[eid].wrapping_add(1);
        self.revocations += 1;
        self.inflight[job_id] -= exec.busy_slots() as u32;
        let job = self.jobs[job_id].as_mut().expect("revoke on retired job");
        let slot = job.framework;
        for t in 0..job.tasks.len() {
            let (_, requeue) = job.tasks[t].revoke_executor(eid);
            if requeue {
                job.requeue_task(t);
                self.reattempts += 1;
            }
        }
        job.executors.retain(|&e| e != eid);
        self.free_execs.push(eid);
        self.live_execs -= 1;
        self.master.revoke(slot, exec.agent, &exec.demand, 1.0)
    }

    /// Kill-based preemption (`--preempt`): for each deadline-class job
    /// that is starved (active, wants executors, has none live or pending),
    /// pick one executor of a strictly-lower-priority job whose eviction
    /// makes the requester placeable, and schedule its revocation *now*.
    /// Victim selection is [`crate::scheduler::Policy::select_victim`] —
    /// fully deterministic, no RNG draws. Strictly-descending priority
    /// means preemption chains terminate.
    fn maybe_preempt(&mut self, now: f64) {
        let Some(preempt) = self.cfg.preempt else { return };
        let total = self.master.state.pool.total_capacity();
        let mut chosen: Vec<usize> = Vec::new();
        for rid in 0..self.jobs.len() {
            let Some(req) = self.jobs[rid].as_ref() else { continue };
            if req.class.deadline.is_none()
                || req.is_finished()
                || !req.executors.is_empty()
                || req.pending_executors > 0
                || req.executors_wanted() == 0
            {
                continue;
            }
            let demand = req.spec.executor_demand;
            let priority = req.class.priority;
            let candidates: Vec<PreemptCandidate> = self
                .executors
                .iter()
                .enumerate()
                .filter_map(|(eid, e)| {
                    let e = e.as_ref()?;
                    if e.terminated || chosen.contains(&eid) {
                        return None;
                    }
                    let victim = self.jobs[e.job].as_ref()?;
                    if victim.class.priority >= priority {
                        return None;
                    }
                    let agent = self.master.state.pool.agent(e.agent);
                    // eviction must actually make the requester placeable
                    if !agent.registered
                        || !demand.fits_within(&(agent.residual() + e.demand))
                    {
                        return None;
                    }
                    let share = e.demand.dominant_ratio_over(&total).unwrap_or(0.0);
                    Some(PreemptCandidate {
                        exec: eid,
                        job: e.job,
                        priority: victim.class.priority,
                        share,
                    })
                })
                .collect();
            if let Some(v) = self.master.policy.select_victim(preempt, &candidates) {
                let victim_fw = self.jobs[v.job].as_ref().expect("candidate job live").framework;
                let agent = self.executors[v.exec].as_ref().expect("candidate exec live").agent;
                self.master.record_preempt(victim_fw, agent, self.jobs[rid].as_ref().unwrap().framework);
                self.preemptions += 1;
                chosen.push(v.exec);
                // class 1: the eviction lands before the next Allocate
                self.events.schedule(now, EventKind::ExecutorRevoked { job: v.job, exec: v.exec });
            }
        }
    }

    fn materialize(&mut self, grants: &[Grant], now: f64) -> Result<()> {
        for g in grants {
            let job_id = *self.fw_to_job.get(&g.framework).expect("grant for unknown framework");
            let count = g.count as usize;
            let per_exec = g.amount.scaled(1.0 / g.count);
            for _ in 0..count {
                let exec_id = match self.free_execs.pop() {
                    Some(slot) => slot,
                    None => {
                        self.executors.push(None);
                        self.exec_epoch.push(0);
                        self.executors.len() - 1
                    }
                };
                let job = self.jobs[job_id].as_mut().expect("grant for retired job");
                let slots = job.spec.slots_per_executor;
                let mut exec = Executor::new(exec_id, job_id, g.agent, per_exec, slots);
                job.pending_executors = job.pending_executors.saturating_sub(1);
                job.executors.push(exec_id);
                let dispatches = fill_executor(
                    job,
                    &mut exec,
                    now,
                    self.cfg.speculation,
                    &self.done_durations[job_id],
                );
                self.executors[exec_id] = Some(exec);
                self.live_execs += 1;
                if self.live_execs > self.peak_live_execs {
                    self.peak_live_execs = self.live_execs;
                }
                self.schedule_dispatches(job_id, exec_id, &dispatches, now);
            }
        }
        Ok(())
    }

    fn schedule_dispatches(&mut self, job: JobId, exec: usize, ds: &[Dispatch], now: f64) {
        let _ = now;
        self.inflight[job] += ds.len() as u32;
        let epoch = self.exec_epoch[exec];
        for d in ds {
            self.events.schedule_in(
                d.duration,
                EventKind::TaskFinish {
                    job,
                    exec,
                    task: d.task,
                    attempt: d.attempt,
                    duration: d.duration,
                    epoch,
                },
            );
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_task_finish(
        &mut self,
        job_id: JobId,
        exec_id: usize,
        task: usize,
        attempt: u32,
        duration: f64,
        now: f64,
        compute: &mut dyn TaskCompute,
    ) -> Result<()> {
        self.inflight[job_id] -= 1;
        self.executors[exec_id].as_mut().expect("finish on retired executor").vacate();
        let job = self.jobs[job_id].as_mut().expect("finish on retired job");
        let won = job.tasks[task].finish_attempt(attempt, now);
        if won {
            self.tasks_done += 1;
            self.done_durations[job_id].push(duration);
            let kind = job.spec.kind;
            compute.run_task(kind, (job_id as u64) << 20 | task as u64)?;
            let job_done = self.jobs[job_id].as_mut().unwrap().mark_task_done(task, now);
            if job_done {
                self.complete_job(job_id, now)?;
                self.maybe_retire(job_id);
                return Ok(());
            }
        }
        // keep this executor busy if the job still has work
        if !self.jobs[job_id].as_ref().unwrap().is_finished() {
            let job = self.jobs[job_id].as_mut().unwrap();
            let exec = self.executors[exec_id].as_mut().unwrap();
            let dispatches = fill_executor(
                job,
                exec,
                now,
                self.cfg.speculation,
                &self.done_durations[job_id],
            );
            self.schedule_dispatches(job_id, exec_id, &dispatches, now);
        }
        self.maybe_retire(job_id);
        Ok(())
    }

    /// Recycle a finished job's slab slot once its last in-flight task
    /// event (losing speculative attempts included) has fired — keeps
    /// long replays at O(concurrency) memory instead of O(jobs).
    fn maybe_retire(&mut self, job_id: JobId) {
        let done = matches!(&self.jobs[job_id], Some(j) if j.is_finished())
            && self.inflight[job_id] == 0;
        if !done {
            return;
        }
        let job = self.jobs[job_id].take().expect("retire checked occupancy");
        for eid in job.executors {
            if self.executors[eid].take().is_some() {
                self.free_execs.push(eid);
                self.live_execs -= 1;
            }
        }
        self.done_durations[job_id] = Vec::new();
        self.free_jobs.push(job_id);
    }

    fn complete_job(&mut self, job_id: JobId, now: f64) -> Result<()> {
        self.trace.job_completed(now);
        let job = self.jobs[job_id].as_ref().expect("complete on retired job");
        let queue = job.queue;
        let slot = job.framework;
        let kind_label = job.spec.kind.label();
        let ct = now - job.submitted_at;
        let sd = ct / job.ideal_service();
        let exec_ids = job.executors.clone();
        if let Some(deadline) = job.class.deadline {
            self.deadline_jobs += 1;
            self.tardiness.push((ct - deadline).max(0.0));
            if ct > deadline {
                self.deadline_misses += 1;
            }
        }
        self.completion.push(ct);
        self.slowdown.push(sd);
        let class = self.queues[queue].class.clone();
        let threshold = self.cfg.stats_threshold;
        self.class_slowdown
            .entry(class)
            .or_insert_with(|| StreamingDist::with_threshold(threshold))
            .push(sd);
        if now > self.makespan {
            self.makespan = now;
        }
        self.active_jobs -= 1;
        let entry = self.group_finish.entry(kind_label).or_insert(0.0);
        *entry = entry.max(now);

        // executors terminate with the job (§3.2); their resources reach the
        // allocator staggered by up to release_jitter seconds (§3.5.3)
        for eid in exec_ids {
            let exec = self.executors[eid].as_mut().expect("release on retired executor");
            exec.terminated = true;
            let agent = exec.agent;
            let amount = exec.demand;
            let jitter = self.rng.f64() * self.cfg.release_jitter;
            self.events.schedule_in(
                jitter,
                EventKind::Release { framework: slot, agent, amount, count: 1.0 },
            );
        }
        self.master.finish_framework(slot);
        self.fw_to_job.remove(&slot);
        // a closed queue submits its next job right away; an open queue's
        // next arrival is already in the event horizon
        if self.queues[queue].closed {
            self.events.schedule(now, EventKind::JobArrival { queue });
        }
        Ok(())
    }
}

/// The Spark side of the offer protocol.
struct SparkOfferHandler<'a> {
    jobs: &'a mut Vec<Option<SparkJob>>,
    fw_to_job: &'a HashMap<usize, JobId>,
}

impl OfferHandler for SparkOfferHandler<'_> {
    fn wants(&self, framework: usize) -> bool {
        self.fw_to_job
            .get(&framework)
            .and_then(|j| self.jobs[*j].as_ref())
            .map(|job| job.executors_wanted() > 0)
            .unwrap_or(false)
    }

    fn accept(&mut self, offer: &Offer) -> (f64, ResVec) {
        let Some(&job_id) = self.fw_to_job.get(&offer.framework) else {
            return (0.0, ResVec::zero(offer.resources.len()));
        };
        let Some(job) = self.jobs[job_id].as_mut() else {
            return (0.0, ResVec::zero(offer.resources.len()));
        };
        let d = job.spec.executor_demand;
        let fit = offer.executors_that_fit(&d) as usize;
        let take = fit.min(job.executors_wanted());
        if take == 0 {
            return (0.0, ResVec::zero(offer.resources.len()));
        }
        job.pending_executors += take;
        (take as f64, d.scaled(take as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::scenario::realize;

    fn run(policy: &str, mode: AllocatorMode, seed: u64) -> OnlineResult {
        let mut cfg = OnlineConfig::small(policy, mode);
        cfg.seed = seed;
        OnlineSim::new(cfg).unwrap().run().unwrap()
    }

    #[test]
    fn small_run_completes_all_jobs() {
        let r = run("drf", AllocatorMode::Characterized, 1);
        assert_eq!(r.jobs_completed, 8); // 4 queues x 2 jobs
        assert!(r.makespan > 0.0);
        assert!(r.tasks_done >= 8 * 8);
        assert!(r.mean_cpu > 0.0 && r.mean_mem > 0.0);
        // per-job stats populated and sane
        assert_eq!(r.completion.n, 8);
        assert!(r.completion.p50 > 0.0 && r.completion.max >= r.completion.p50);
        assert!(r.slowdown.p50 >= 1.0 - 1e-9, "slowdown {:?}", r.slowdown);
    }

    #[test]
    fn oblivious_mode_completes_too() {
        let r = run("drf", AllocatorMode::Oblivious, 2);
        assert_eq!(r.jobs_completed, 8);
    }

    #[test]
    fn all_policies_complete_characterized() {
        for p in crate::scheduler::POLICY_NAMES {
            let r = run(p, AllocatorMode::Characterized, 3);
            assert_eq!(r.jobs_completed, 8, "{p}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run("psdsf", AllocatorMode::Characterized, 42);
        let b = run("psdsf", AllocatorMode::Characterized, 42);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.grants, b.grants);
        assert_eq!(a.trace.cpu.values(), b.trace.cpu.values());
    }

    #[test]
    fn seeds_change_trajectories() {
        let a = run("drf", AllocatorMode::Characterized, 1);
        let b = run("drf", AllocatorMode::Characterized, 2);
        assert!(a.makespan != b.makespan || a.trace.cpu.values() != b.trace.cpu.values());
    }

    #[test]
    fn staged_registration_runs() {
        let mut cfg = OnlineConfig::paper_staged("rpsdsf", 1);
        for q in &mut cfg.queues {
            q.workload.tasks_per_job = 6;
            q.workload.max_executors = 3;
        }
        cfg.queues.truncate(4);
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 4);
    }

    #[test]
    fn utilization_bounded() {
        let r = run("rpsdsf", AllocatorMode::Characterized, 7);
        for &v in r.trace.cpu.values() {
            assert!((0.0..=1.0 + 1e-9).contains(&v));
        }
        for &v in r.trace.mem.values() {
            assert!((0.0..=1.0 + 1e-9).contains(&v));
        }
    }

    #[test]
    fn open_arrivals_complete_and_respect_times() {
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        for q in &mut cfg.queues {
            q.arrival = ArrivalProcess::Poisson { rate: 0.05 };
        }
        cfg.seed = 13;
        let scenario = realize(&cfg, "test-open");
        let first_arrival = scenario
            .queues
            .iter()
            .flat_map(|q| q.arrivals.iter().copied())
            .fold(f64::INFINITY, f64::min);
        let r = OnlineSim::with_scenario(cfg, scenario).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8);
        // nothing can finish before the first arrival
        assert!(r.makespan > first_arrival);
    }

    #[test]
    fn scripted_churn_drains_and_rejoins() {
        let mut cfg = OnlineConfig::small("rpsdsf", AllocatorMode::Characterized);
        cfg.seed = 17;
        // take two agents out for a mid-run window
        cfg.churn = ChurnModel::Scripted(vec![
            ChurnEvent::new(10.0, 4, false),
            ChurnEvent::new(10.0, 5, false),
            ChurnEvent::new(90.0, 4, true),
            ChurnEvent::new(90.0, 5, true),
        ]);
        let r = OnlineSim::new(cfg.clone()).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8, "churn must not lose jobs");
        // the outage genuinely alters the run (2 of 6 agents gone for most
        // of it) but the workload itself is identical (same seed streams)
        cfg.churn = ChurnModel::None;
        let base = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(base.jobs_completed, 8);
        assert!(
            base.makespan != r.makespan || base.trace.cpu.values() != r.trace.cpu.values(),
            "an 80s outage of a third of the cluster left no trace"
        );
    }

    #[test]
    fn queue_weight_reaches_framework_registration() {
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        cfg.queues[0].weight = 2.0;
        let scenario = realize(&cfg, "weighted");
        assert_eq!(scenario.queues[0].weight, 2.0, "realize must carry the queue weight");
        assert_eq!(scenario.queues[1].weight, 1.0);
        let mut sim = OnlineSim::with_scenario(cfg, scenario).unwrap();
        sim.on_job_arrival(0, 0.0, false).unwrap();
        sim.on_job_arrival(1, 0.0, false).unwrap();
        assert_eq!(sim.master.state.framework(0).weight, 2.0);
        assert_eq!(sim.master.state.framework(1).weight, 1.0);
    }

    #[test]
    fn weighted_run_still_completes() {
        let mut cfg = OnlineConfig::small("psdsf", AllocatorMode::Characterized);
        cfg.queues[0].weight = 2.0;
        cfg.seed = 11;
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8);
    }

    #[test]
    fn scenario_dim_mismatch_rejected() {
        let cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        let mut wrong_agents = realize(&cfg, "x");
        wrong_agents.agents = 3;
        assert!(OnlineSim::with_scenario(cfg.clone(), wrong_agents).is_err());
        let mut wrong_kinds = realize(&cfg, "x");
        wrong_kinds.kinds = 3;
        assert!(OnlineSim::with_scenario(cfg, wrong_kinds).is_err());
    }

    #[test]
    fn sharded_run_bit_identical_to_serial() {
        let mut serial = OnlineConfig::small("rpsdsf", AllocatorMode::Characterized);
        serial.seed = 21;
        let mut sharded = serial.clone();
        sharded.shards = 4;
        let a = OnlineSim::new(serial).unwrap().run().unwrap();
        let b = OnlineSim::new(sharded).unwrap().run().unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.grants, b.grants);
        assert_eq!(a.trace.cpu.values(), b.trace.cpu.values());
        assert_eq!(a.trace.mem.values(), b.trace.mem.values());
    }

    #[test]
    fn obs_run_matches_silent_run_and_summarizes() {
        let mut cfg = OnlineConfig::small("psdsf", AllocatorMode::Characterized);
        cfg.seed = 29;
        let silent = OnlineSim::new(cfg.clone()).unwrap().run().unwrap();
        assert!(silent.obs.is_none(), "no recorder unless asked");
        cfg.obs = true;
        let traced = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(silent.makespan, traced.makespan, "tracing changed the run");
        assert_eq!(silent.grants, traced.grants);
        assert_eq!(silent.trace.cpu.values(), traced.trace.cpu.values());
        let s = traced.obs.expect("summary attached");
        assert!(s.cycles > 0);
        assert!(!s.events.is_empty());
        assert_eq!(s.dropped, 0, "small run fits the ring");
        assert!(s.counters.full_rescores > 0);
        // every phase present in the histogram table
        assert_eq!(s.phases.len(), crate::obs::ObsPhase::ALL.len());
    }

    #[test]
    fn churn_scenario_from_registry_completes() {
        let cfg = crate::workload::scenario::scenario_config(
            "churn",
            "drf",
            AllocatorMode::Characterized,
            Some(1),
            23,
        )
        .unwrap();
        let expected: usize = cfg.queues.iter().map(|q| q.jobs).sum();
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, expected);
    }

    #[test]
    fn streamed_run_matches_eager_scenario_run() {
        // the lazily-streamed workload must drive the simulator through the
        // exact same trajectory as its eager realization
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        for q in &mut cfg.queues {
            q.arrival = ArrivalProcess::Poisson { rate: 0.05 };
        }
        cfg.seed = 31;
        let scenario = realize(&cfg, "adhoc");
        let eager = OnlineSim::with_scenario(cfg.clone(), scenario).unwrap().run().unwrap();
        let lazy = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(eager.makespan, lazy.makespan);
        assert_eq!(eager.grants, lazy.grants);
        assert_eq!(eager.completion, lazy.completion);
        assert_eq!(eager.slowdown, lazy.slowdown);
        assert_eq!(eager.trace.cpu.values(), lazy.trace.cpu.values());
        assert_eq!(eager.trace.mem.values(), lazy.trace.mem.values());
    }

    #[test]
    fn stream_stats_report_lookahead_and_classes() {
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        for q in &mut cfg.queues {
            q.arrival = ArrivalProcess::Poisson { rate: 0.05 };
        }
        cfg.seed = 37;
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.stream.jobs_streamed, 8);
        // open queues hold exactly one pulled arrival each in the horizon
        assert!(r.stream.max_lookahead >= 1);
        assert!(r.stream.max_lookahead <= 8);
        assert_eq!(r.stream.parse_errors, 0);
        assert!(r.stream.peak_active_jobs >= 1);
        assert!(r.stream.peak_live_executors >= 1);
        // per-class slowdowns cover every workload class and sum to the total
        let class_n: usize = r.class_slowdown.iter().map(|(_, d)| d.n).sum();
        assert_eq!(class_n, 8);
        for (class, d) in &r.class_slowdown {
            assert!(!class.is_empty());
            assert!(d.p50 >= 1.0 - 1e-9, "{class}: {d:?}");
        }
    }

    #[test]
    fn scripted_kills_lose_work_but_jobs_still_complete() {
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        cfg.seed = 43;
        // kill two agents mid-run while work is in flight, bring them back
        cfg.churn = ChurnModel::Scripted(vec![
            ChurnEvent::kill(8.0, 4),
            ChurnEvent::kill(8.0, 5),
            ChurnEvent::new(120.0, 4, true),
            ChurnEvent::new(120.0, 5, true),
        ]);
        let r = OnlineSim::new(cfg.clone()).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8, "kills must not lose jobs");
        assert!(r.revocations > 0, "agents 4/5 had executors at t=8");
        assert!(r.reattempts > 0, "in-flight tasks were lost and re-queued");
        assert_eq!(r.preemptions, 0, "no preemption policy configured");
        // drain-based churn at the same times differs: kills redo work
        cfg.churn = ChurnModel::Scripted(vec![
            ChurnEvent::new(8.0, 4, false),
            ChurnEvent::new(8.0, 5, false),
            ChurnEvent::new(120.0, 4, true),
            ChurnEvent::new(120.0, 5, true),
        ]);
        let drain = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(drain.revocations, 0);
        assert!(
            drain.makespan != r.makespan || drain.trace.cpu.values() != r.trace.cpu.values(),
            "losing in-flight work must alter the trajectory vs draining"
        );
    }

    #[test]
    fn kill_runs_are_deterministic_under_crn() {
        for policy in ["drf", "psdsf"] {
            let mut cfg = OnlineConfig::small(policy, AllocatorMode::Characterized);
            cfg.seed = 47;
            cfg.churn = ChurnModel::Kill {
                min_up: 3,
                mean_up: 60.0,
                mean_down: 30.0,
                horizon: 600.0,
            };
            let a = OnlineSim::new(cfg.clone()).unwrap().run().unwrap();
            let b = OnlineSim::new(cfg).unwrap().run().unwrap();
            assert_eq!(a.jobs_completed, 8, "{policy}");
            assert_eq!(a.makespan, b.makespan, "{policy}");
            assert_eq!(a.revocations, b.revocations, "{policy}");
            assert_eq!(a.reattempts, b.reattempts, "{policy}");
            assert_eq!(a.completion, b.completion, "{policy}");
            assert_eq!(a.trace.cpu.values(), b.trace.cpu.values(), "{policy}");
        }
    }

    #[test]
    fn kill_of_agent_with_zero_executors_is_harmless() {
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        cfg.seed = 53;
        // t=0.5: nothing has been allocated yet (allocation_interval = 1.0)
        cfg.churn = ChurnModel::Scripted(vec![
            ChurnEvent::kill(0.5, 5),
            ChurnEvent::new(30.0, 5, true),
        ]);
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.revocations, 0, "no executors existed on the killed agent");
    }

    #[test]
    fn preempt_deadline_scenario_completes_and_tracks_slo() {
        let cfg = crate::workload::scenario::scenario_config(
            "preempt-deadline",
            "drf",
            AllocatorMode::Characterized,
            Some(2),
            59,
        )
        .unwrap();
        let expected: usize = cfg.queues.iter().map(|q| q.jobs).sum();
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, expected);
        // queues 0–3 are deadline-class: 4 queues × 2 jobs
        assert_eq!(r.deadline_jobs, 8);
        assert!(r.deadline_misses <= r.deadline_jobs);
        assert_eq!(r.tardiness.n, 8, "one tardiness sample per deadline job");
        assert!(r.tardiness.p99 >= 0.0);
        assert_eq!(r.preemptions, r.revocations, "only preemption revokes here");
    }

    #[test]
    fn revocation_scenario_from_registry_completes() {
        let cfg = crate::workload::scenario::scenario_config(
            "revocation",
            "drf",
            AllocatorMode::Characterized,
            Some(1),
            61,
        )
        .unwrap();
        let expected: usize = cfg.queues.iter().map(|q| q.jobs).sum();
        let r = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, expected);
    }

    #[test]
    fn preemption_off_is_bit_identical_to_pre_slo_runs() {
        // zero-cost-when-off: the preempt hook must not perturb anything —
        // same grants, same trace, same RNG consumption
        let mut cfg = OnlineConfig::small("psdsf", AllocatorMode::Characterized);
        cfg.seed = 67;
        let base = OnlineSim::new(cfg.clone()).unwrap().run().unwrap();
        cfg.queues[0].class = JobClass::new(Some(1e9), 5); // classes alone: no-op
        let classed = OnlineSim::new(cfg).unwrap().run().unwrap();
        assert_eq!(base.makespan, classed.makespan);
        assert_eq!(base.grants, classed.grants);
        assert_eq!(base.trace.cpu.values(), classed.trace.cpu.values());
        assert_eq!(classed.deadline_jobs, 2, "but SLO accounting sees them");
    }

    #[test]
    fn slab_recycles_job_slots_on_long_closed_runs() {
        // 1 queue x 6 jobs, closed: at most one job is ever active, so the
        // slab must stay O(1) instead of O(jobs)
        let mut cfg = OnlineConfig::small("drf", AllocatorMode::Characterized);
        cfg.queues.truncate(1);
        cfg.queues[0].jobs = 6;
        cfg.seed = 41;
        let sim = OnlineSim::new(cfg).unwrap();
        let r = sim.run().unwrap();
        assert_eq!(r.jobs_completed, 6);
        assert_eq!(r.stream.peak_active_jobs, 1);
        assert_eq!(r.completion.n, 6);
    }
}

//! Discrete-event simulation substrate.
//!
//! The paper's online experiments ran wall-clock hours on AWS; we replay the
//! same dynamics deterministically: an event queue drives the Mesos master
//! ([`crate::mesos`]) and the Spark jobs ([`crate::spark`]), while a trace
//! recorder samples the allocated CPU/memory fractions Figures 3–9 plot.

pub mod engine;
pub mod events;
pub mod online;
pub mod runner;
pub mod trace;

pub use engine::EventQueue;
pub use events::EventKind;
pub use online::{OnlineConfig, OnlineResult, OnlineSim, QueueSpec};
pub use trace::TraceRecorder;

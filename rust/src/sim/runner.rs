//! Multi-trial runner: fan independent seeded runs across threads
//! (std::thread — tokio is unavailable offline, and the trials are pure
//! CPU-bound closures with no I/O).

use crate::rng::Rng;
use std::thread;

/// Run `trials` instances of `f(trial_index, trial_seed)` across up to
/// `threads` worker threads, preserving result order. Seeds derive from
/// `seed` via independent PCG streams, so results are identical regardless
/// of thread count.
pub fn run_trials<T, F>(trials: usize, seed: u64, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, u64) -> T + Sync,
{
    let root = Rng::new(seed);
    let seeds: Vec<u64> = (0..trials).map(|i| root.split(i as u64).next_u64()).collect();
    let threads = threads.max(1).min(trials.max(1));
    if threads == 1 {
        return seeds.iter().enumerate().map(|(i, s)| f(i, *s)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut results: Vec<Option<T>> = (0..trials).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<Option<T>>> =
        (0..trials).map(|_| std::sync::Mutex::new(None)).collect();
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= trials {
                    break;
                }
                let out = f(i, seeds[i]);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        results[i] = slot.into_inner().unwrap();
    }
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Default worker-thread count: physical parallelism minus one, at least 1.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_count() {
        let out = run_trials(10, 1, 4, |i, _s| i * 2);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn seeds_stable_across_thread_counts() {
        let a = run_trials(8, 99, 1, |_i, s| s);
        let b = run_trials(8, 99, 4, |_i, s| s);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_distinct() {
        let s = run_trials(16, 5, 2, |_i, s| s);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), s.len());
    }

    #[test]
    fn zero_trials() {
        let out: Vec<u64> = run_trials(0, 1, 4, |_i, s| s);
        assert!(out.is_empty());
    }
}

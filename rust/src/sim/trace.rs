//! Trace recorder: samples the cluster-level allocated fractions the
//! figures plot, plus job-completion marks.

use crate::cluster::AgentPool;
use crate::metrics::TimeSeries;

/// Records the utilization time series of one online run.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    /// Allocated CPU fraction over time (figures' left axis).
    pub cpu: TimeSeries,
    /// Allocated memory fraction over time.
    pub mem: TimeSeries,
    /// (time, jobs-completed-so-far) marks.
    pub completions: Vec<(f64, usize)>,
    completed: usize,
}

impl TraceRecorder {
    pub fn new(label: &str) -> Self {
        TraceRecorder {
            cpu: TimeSeries::new(format!("{label} cpu")),
            mem: TimeSeries::new(format!("{label} mem")),
            completions: Vec::new(),
            completed: 0,
        }
    }

    /// Sample the pool's allocated fractions at time `t`.
    pub fn sample(&mut self, t: f64, pool: &AgentPool) {
        let u = pool.utilization();
        self.cpu.push(t, u.first().copied().unwrap_or(0.0));
        self.mem.push(t, u.get(1).copied().unwrap_or(0.0));
    }

    /// Record a job completion at time `t`.
    pub fn job_completed(&mut self, t: f64) {
        self.completed += 1;
        self.completions.push((t, self.completed));
    }

    pub fn jobs_completed(&self) -> usize {
        self.completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{AgentPool, ServerType};
    use crate::resources::ResVec;

    #[test]
    fn samples_pool_utilization() {
        let mut pool = AgentPool::new(&ServerType::paper_homogeneous());
        let mut tr = TraceRecorder::new("test");
        tr.sample(0.0, &pool);
        pool.reserve(0, &ResVec::cpu_mem(6.0, 11.0)).unwrap();
        tr.sample(10.0, &pool);
        assert_eq!(tr.cpu.values()[0], 0.0);
        assert!((tr.cpu.values()[1] - 6.0 / 36.0).abs() < 1e-12);
        assert!((tr.mem.values()[1] - 11.0 / 66.0).abs() < 1e-12);
    }

    #[test]
    fn counts_completions() {
        let mut tr = TraceRecorder::new("t");
        tr.job_completed(1.0);
        tr.job_completed(2.0);
        assert_eq!(tr.jobs_completed(), 2);
        assert_eq!(tr.completions, vec![(1.0, 1), (2.0, 2)]);
    }
}

"""pi_mc and wordcount kernels vs their oracles + statistical sanity."""

import math

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import PI_SAMPLES, WC_TOKENS, WC_VOCAB, pi_mc, ref, wordcount


# --- pi_mc -------------------------------------------------------------------

def test_pi_kernel_matches_ref():
    for seed in [0, 1, 42, 123456, 2**31 - 1]:
        s = np.array([seed], dtype=np.int32)
        got = np.asarray(pi_mc.pi_hits(s))
        want = np.asarray(ref.pi_hits(s, PI_SAMPLES))
        np.testing.assert_array_equal(got, want)


def test_pi_deterministic():
    s = np.array([7], dtype=np.int32)
    a = np.asarray(pi_mc.pi_hits(s))
    b = np.asarray(pi_mc.pi_hits(s))
    np.testing.assert_array_equal(a, b)


def test_pi_seeds_differ():
    a = int(np.asarray(pi_mc.pi_hits(np.array([1], np.int32)))[0])
    b = int(np.asarray(pi_mc.pi_hits(np.array([2], np.int32)))[0])
    assert a != b


def test_pi_estimate_accuracy():
    """Aggregated over 32 rounds the estimate should be within ~3 sigma.

    sigma for one Bernoulli(p=pi/4) sample batch of K: sqrt(p(1-p)/K); with
    32*16384 samples sigma(pi_hat) ~ 4*sqrt(p(1-p)/524288) ~ 0.0023.
    """
    total = 0
    rounds = 32
    for seed in range(rounds):
        total += int(np.asarray(pi_mc.pi_hits(np.array([seed], np.int32)))[0])
    est = 4.0 * total / (rounds * PI_SAMPLES)
    assert abs(est - math.pi) < 0.01, est


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(-(2**31), 2**31 - 1))
def test_pi_kernel_matches_ref_hypothesis(seed):
    s = np.array([seed], dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(pi_mc.pi_hits(s)), np.asarray(ref.pi_hits(s, PI_SAMPLES))
    )


def test_pi_hash_uniformity():
    """Chi-square smoke test of the counter hash over 16 buckets."""
    s = np.array([99], dtype=np.int32)
    i = np.arange(PI_SAMPLES, dtype=np.uint32)
    import jax.numpy as jnp
    hx = np.asarray(ref._mix(i * np.uint32(0x9E3779B9) + np.uint32(99)))
    buckets = np.bincount((hx >> 28).astype(np.int64), minlength=16)
    expected = PI_SAMPLES / 16
    chi2 = float(np.sum((buckets - expected) ** 2 / expected))
    # 15 dof, p=0.001 critical value ~ 37.7
    assert chi2 < 37.7, (chi2, buckets)


# --- wordcount ---------------------------------------------------------------

def test_wc_kernel_matches_ref_uniform():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, WC_VOCAB, size=WC_TOKENS).astype(np.int32)
    got = np.asarray(wordcount.wordcount_hist(toks))
    want = np.asarray(ref.wordcount_hist(toks, WC_VOCAB))
    np.testing.assert_allclose(got, want)


def test_wc_matches_numpy_bincount():
    rng = np.random.default_rng(1)
    toks = rng.integers(0, WC_VOCAB, size=WC_TOKENS).astype(np.int32)
    got = np.asarray(wordcount.wordcount_hist(toks)).astype(np.int64)
    want = np.bincount(toks, minlength=WC_VOCAB)
    np.testing.assert_array_equal(got, want)


def test_wc_total_preserved():
    rng = np.random.default_rng(2)
    toks = rng.integers(0, WC_VOCAB, size=WC_TOKENS).astype(np.int32)
    got = np.asarray(wordcount.wordcount_hist(toks))
    assert float(got.sum()) == WC_TOKENS


def test_wc_out_of_range_dropped():
    toks = np.full(WC_TOKENS, -1, dtype=np.int32)
    toks[:10] = 3
    got = np.asarray(wordcount.wordcount_hist(toks))
    assert float(got.sum()) == 10.0
    assert got[3] == 10.0


def test_wc_skewed_distribution():
    """Zipf-ish skew (like real word frequencies) round-trips exactly."""
    rng = np.random.default_rng(3)
    zipf = np.minimum(rng.zipf(1.5, size=WC_TOKENS), WC_VOCAB) - 1
    toks = zipf.astype(np.int32)
    got = np.asarray(wordcount.wordcount_hist(toks)).astype(np.int64)
    want = np.bincount(toks, minlength=WC_VOCAB)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), hi=st.integers(1, WC_VOCAB))
def test_wc_kernel_matches_ref_hypothesis(seed, hi):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, hi, size=WC_TOKENS).astype(np.int32)
    got = np.asarray(wordcount.wordcount_hist(toks))
    want = np.asarray(ref.wordcount_hist(toks, WC_VOCAB))
    np.testing.assert_allclose(got, want)

"""Pallas scores kernel vs pure-jnp oracle — the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import BIG, M_MAX, N_MAX, R_MAX, ref, scores
from .helpers import make_instance, paper_instance, random_instance

NAMES = ["drf", "tsf", "psdsf", "rpsdsf", "fit", "feas"]


def assert_scores_match(inst, atol=1e-4, rtol=1e-5):
    got = scores.allocation_scores(*inst)
    want = ref.allocation_scores(*inst)
    for name, g, w in zip(NAMES, got, want):
        g = np.asarray(g)
        w = np.asarray(w)
        # compare BIG slots exactly, finite slots with allclose
        gb, wb = g >= BIG / 2, w >= BIG / 2
        np.testing.assert_array_equal(gb, wb, err_msg=f"{name}: BIG mask differs")
        np.testing.assert_allclose(g[~gb], w[~wb], atol=atol, rtol=rtol,
                                   err_msg=f"{name}: finite values differ")


def test_paper_instance_empty():
    assert_scores_match(paper_instance())


def test_paper_instance_allocated():
    # BF-DRF's final state from Table 1: x = [[20, 2], [0, 19]]
    assert_scores_match(paper_instance(x=[[20.0, 2.0], [0.0, 19.0]]))


def test_drf_values_paper():
    """Hand-checked DRF dominant shares on the §2 example."""
    inst = paper_instance(x=[[4.0, 2.0], [1.0, 5.0]])
    drf = np.asarray(ref.drf_shares(*inst))
    # C = (130, 130); x_1 = 6, d_1 = (5,1) -> 30/130; x_2 = 6, d_2=(1,5) -> 30/130
    np.testing.assert_allclose(drf[0], 30.0 / 130.0, rtol=1e-6)
    np.testing.assert_allclose(drf[1], 30.0 / 130.0, rtol=1e-6)
    assert np.all(drf[2:] >= BIG / 2)


def test_tsf_nstar_paper():
    """N*_1 = min(100/5,30/1)+min(30/5,100/1) = 20+6 = 26 on the §2 example."""
    inst = paper_instance(x=[[13.0, 13.0], [0.0, 0.0]])
    tsf = np.asarray(ref.tsf_shares(*inst))
    np.testing.assert_allclose(tsf[0], 26.0 / 26.0, rtol=1e-6)
    np.testing.assert_allclose(tsf[1], 0.0, atol=1e-9)


def test_psdsf_values_paper():
    """K_{n,i} = x_n * max_r d_nr/c_ir."""
    inst = paper_instance(x=[[2.0, 0.0], [0.0, 3.0]])
    ps = np.asarray(ref.psdsf_scores(*inst))
    # framework 1: x=2, server 1: max(5/100, 1/30) = 1/20 -> 0.1
    np.testing.assert_allclose(ps[0, 0], 2.0 * 5.0 / 100.0, rtol=1e-6)
    # framework 1, server 2: max(5/30, 1/100) = 1/6 -> 2/6
    np.testing.assert_allclose(ps[0, 1], 2.0 * 5.0 / 30.0, rtol=1e-6)
    # framework 2, server 1: max(1/100, 5/30) -> 3 * 1/6
    np.testing.assert_allclose(ps[1, 0], 3.0 * 5.0 / 30.0, rtol=1e-6)


def test_rpsdsf_uses_residuals():
    inst = paper_instance(x=[[1.0, 0.0], [0.0, 0.0]])
    rps = np.asarray(ref.rpsdsf_scores(*inst))
    # server 1 residual after one f1 task: (95, 29); f1: max(5/95, 1/29) = 5/95
    np.testing.assert_allclose(rps[0, 0], 1.0 * 5.0 / 95.0, rtol=1e-6)
    # framework 2 has x=0 -> score 0 everywhere feasible
    np.testing.assert_allclose(rps[1, 0], 0.0, atol=1e-9)


def test_rpsdsf_exhausted_server_big():
    # fill server 1 cpu exactly: 20 tasks of f1 use (100, 20)
    inst = paper_instance(x=[[20.0, 0.0], [0.0, 0.0]])
    rps = np.asarray(ref.rpsdsf_scores(*inst))
    assert rps[0, 0] >= BIG / 2  # no residual cpu left
    assert rps[1, 0] >= BIG / 2  # f2 also needs cpu


def test_feasibility_boundary():
    # after 20 f1 tasks on server 1, residual = (0, 10): nothing fits
    inst = paper_instance(x=[[20.0, 0.0], [0.0, 0.0]])
    feas = np.asarray(ref.feasibility(inst[0], inst[1], inst[2], inst[5], inst[6], inst[7]))
    assert feas[0, 0] == 0.0
    assert feas[1, 0] == 0.0
    assert feas[0, 1] == 1.0 and feas[1, 1] == 1.0


def test_bestfit_prefers_matching_server():
    """Profile match: cpu-heavy f1 -> cpu-rich server 1, mem-heavy f2 -> server 2.

    This is the property that makes BF-DRF reproduce Table 1 (x_{2,1} = 0):
    fit = max_r d/res, so f1 scores 5/100 on s1 vs 5/30 on s2, and f2 the
    mirror image.
    """
    inst = paper_instance()
    fit = np.asarray(ref.bestfit_ratio(inst[0], inst[1], inst[2], inst[5], inst[6], inst[7]))
    np.testing.assert_allclose(fit[0, 0], 5.0 / 100.0, rtol=1e-6)
    np.testing.assert_allclose(fit[0, 1], 5.0 / 30.0, rtol=1e-6)
    np.testing.assert_allclose(fit[1, 0], 5.0 / 30.0, rtol=1e-6)
    np.testing.assert_allclose(fit[1, 1], 5.0 / 100.0, rtol=1e-6)
    assert fit[0, 0] < fit[0, 1] and fit[1, 1] < fit[1, 0]


def test_padding_slots_are_big():
    got = scores.allocation_scores(*paper_instance())
    drf, tsf, ps, rps, fit, feas = [np.asarray(a) for a in got]
    assert np.all(drf[2:] >= BIG / 2)
    assert np.all(tsf[2:] >= BIG / 2)
    assert np.all(ps[2:, :] >= BIG / 2)
    assert np.all(ps[:, 2:] >= BIG / 2)
    assert np.all(feas[2:, :] == 0.0)
    assert np.all(feas[:, 2:] == 0.0)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_ref_random(seed):
    rng = np.random.default_rng(seed)
    assert_scores_match(random_instance(rng))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, N_MAX),
    m=st.integers(1, M_MAX),
    r=st.integers(1, R_MAX),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_shapes(n, m, r, seed):
    rng = np.random.default_rng(seed)
    assert_scores_match(random_instance(rng, n=n, m=m, r=r))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_ref_unallocated(seed):
    rng = np.random.default_rng(seed)
    assert_scores_match(random_instance(rng, allocated=False))


def test_zero_demand_framework_scores_big():
    c = [[50.0, 50.0]]
    d = [[0.0, 0.0], [1.0, 1.0]]
    x = [[0.0], [0.0]]
    inst = make_instance(c, x, d)
    drf, tsf, ps, rps, fit, feas = [np.asarray(a) for a in ref.allocation_scores(*inst)]
    assert drf[0] >= BIG / 2 and tsf[0] >= BIG / 2
    assert np.all(ps[0] >= BIG / 2) and np.all(rps[0] >= BIG / 2)
    assert np.all(fit[0] >= BIG / 2)
    assert np.all(feas[0] == 0.0)
    assert drf[1] == 0.0  # unallocated real framework has zero share


def test_weights_scale_shares():
    inst_w1 = make_instance([[100.0, 100.0]], [[10.0]], [[1.0, 1.0]], phi=[1.0])
    inst_w2 = make_instance([[100.0, 100.0]], [[10.0]], [[1.0, 1.0]], phi=[2.0])
    d1 = np.asarray(ref.drf_shares(*inst_w1))[0]
    d2 = np.asarray(ref.drf_shares(*inst_w2))[0]
    np.testing.assert_allclose(d1, 2.0 * d2, rtol=1e-6)


def test_role_aggregation_shares():
    """Two same-role frameworks share one DRF/PS-DSF score (Mesos roles)."""
    c = [[100.0, 30.0], [30.0, 100.0]]
    d = [[5.0, 1.0], [5.0, 1.0], [1.0, 5.0]]
    x = [[2.0, 0.0], [3.0, 0.0], [0.0, 4.0]]
    inst = make_instance(c, x, d, roles=[0, 0, 1])
    drf = np.asarray(ref.drf_shares(*inst))
    # role 0 total = 5 tasks -> share 25/130 for BOTH members
    np.testing.assert_allclose(drf[0], 25.0 / 130.0, rtol=1e-6)
    np.testing.assert_allclose(drf[1], 25.0 / 130.0, rtol=1e-6)
    np.testing.assert_allclose(drf[2], 20.0 / 130.0, rtol=1e-6)
    # kernel agrees
    assert_scores_match(inst)


def test_identity_rolemat_is_per_framework():
    a = make_instance([[50.0, 50.0]], [[2.0], [3.0]], [[1.0, 1.0], [1.0, 1.0]])
    b = make_instance([[50.0, 50.0]], [[2.0], [3.0]], [[1.0, 1.0], [1.0, 1.0]], roles=[0, 1])
    for ga, gb in zip(ref.allocation_scores(*a), ref.allocation_scores(*b)):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gb))


def test_role_aggregation_does_not_change_residuals():
    """Feasibility/fit use raw per-framework x even with shared roles."""
    c = [[10.0, 10.0]]
    d = [[2.0, 2.0], [2.0, 2.0]]
    x = [[2.0], [2.0]]
    same = make_instance(c, x, d, roles=[0, 0])
    diff = make_instance(c, x, d, roles=[0, 1])
    fs = np.asarray(ref.feasibility(same[0], same[1], same[2], same[5], same[6], same[7]))
    fd = np.asarray(ref.feasibility(diff[0], diff[1], diff[2], diff[5], diff[6], diff[7]))
    np.testing.assert_array_equal(fs, fd)
    # residual (2,2): one more task fits either framework
    assert fs[0, 0] == 1.0 and fs[1, 0] == 1.0

"""Shared test helpers: padded cluster-instance builders.

An "instance" is the tuple of padded arrays the scores kernel consumes:
(c, x, d, phi, fmask, smask, rmask). ``make_instance`` builds one from dense
(unpadded) numpy arrays; ``paper_instance`` is the illustrative example of
the paper's §2 (eq. (1)-(2)) that Tables 1-4 are computed from.
"""

import numpy as np

from compile.kernels import M_MAX, N_MAX, R_MAX


def make_instance(c, x, d, phi=None, roles=None):
    """Pad dense arrays (n x m x r real dims) into the kernel's fixed shapes."""
    c = np.asarray(c, dtype=np.float32)
    x = np.asarray(x, dtype=np.float32)
    d = np.asarray(d, dtype=np.float32)
    m, r = c.shape
    n = d.shape[0]
    assert x.shape == (n, m), (x.shape, n, m)
    assert d.shape == (n, r)
    assert n <= N_MAX and m <= M_MAX and r <= R_MAX
    if phi is None:
        phi = np.ones(n, dtype=np.float32)
    cp = np.zeros((M_MAX, R_MAX), np.float32)
    xp = np.zeros((N_MAX, M_MAX), np.float32)
    dp = np.zeros((N_MAX, R_MAX), np.float32)
    pp = np.ones(N_MAX, np.float32)
    cp[:m, :r] = c
    xp[:n, :m] = x
    dp[:n, :r] = d
    pp[:n] = phi
    fmask = np.zeros(N_MAX, np.float32)
    fmask[:n] = 1.0
    smask = np.zeros(M_MAX, np.float32)
    smask[:m] = 1.0
    rmask = np.zeros(R_MAX, np.float32)
    rmask[:r] = 1.0
    rolemat = np.eye(N_MAX, dtype=np.float32)
    if roles is not None:
        assert len(roles) == n
        for a in range(n):
            for b in range(n):
                rolemat[a, b] = 1.0 if roles[a] == roles[b] else 0.0
    return cp, xp, dp, pp, rolemat, fmask, smask, rmask


def paper_instance(x=None):
    """The §2 illustrative example: d1=(5,1), d2=(1,5); c1=(100,30), c2=(30,100)."""
    c = [[100.0, 30.0], [30.0, 100.0]]
    d = [[5.0, 1.0], [1.0, 5.0]]
    if x is None:
        x = [[0.0, 0.0], [0.0, 0.0]]
    return make_instance(c, x, d)


def random_instance(rng, n=None, m=None, r=None, allocated=True):
    """Random feasible instance for hypothesis/fuzz sweeps."""
    n = n or int(rng.integers(1, N_MAX + 1))
    m = m or int(rng.integers(1, M_MAX + 1))
    r = r or int(rng.integers(1, R_MAX + 1))
    c = rng.uniform(10.0, 200.0, size=(m, r)).astype(np.float32)
    d = rng.uniform(0.5, 8.0, size=(n, r)).astype(np.float32)
    # occasionally zero out a demand dimension (framework ignores a resource)
    mask = rng.random((n, r)) < 0.15
    d[mask] = 0.0
    x = np.zeros((n, m), np.float32)
    if allocated:
        # allocate a few random tasks without (necessarily) exceeding capacity
        for _ in range(int(rng.integers(0, 4 * n))):
            ni = int(rng.integers(0, n))
            mi = int(rng.integers(0, m))
            x[ni, mi] += 1.0
    phi = rng.uniform(0.5, 2.0, size=n).astype(np.float32)
    return make_instance(c, x, d, phi)

"""AOT lowering smoke tests: every artifact lowers to parseable HLO text."""

import json
import os

import pytest

from compile import aot
from compile.kernels import M_MAX, N_MAX, PI_SAMPLES, R_MAX, WC_TOKENS, WC_VOCAB


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out))
    return out, manifest


def test_all_artifacts_written(built):
    out, manifest = built
    for name in ["scores", "utilization", "pi_mc", "wordcount"]:
        assert name in manifest["artifacts"]
        path = out / f"{name}.hlo.txt"
        assert path.exists() and path.stat().st_size > 0


def test_hlo_text_has_entry(built):
    out, manifest = built
    for name in manifest["artifacts"]:
        text = (out / f"{name}.hlo.txt").read_text()
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def test_manifest_dims(built):
    out, manifest = built
    dims = manifest["dims"]
    assert dims["N_MAX"] == N_MAX
    assert dims["M_MAX"] == M_MAX
    assert dims["R_MAX"] == R_MAX
    assert dims["PI_SAMPLES"] == PI_SAMPLES
    assert dims["WC_TOKENS"] == WC_TOKENS
    assert dims["WC_VOCAB"] == WC_VOCAB
    # manifest is valid json on disk too
    loaded = json.loads((out / "manifest.json").read_text())
    assert loaded["dims"] == dims


def test_scores_artifact_inputs(built):
    _, manifest = built
    ins = manifest["artifacts"]["scores"]["inputs"]
    shapes = [tuple(i["shape"]) for i in ins]
    assert shapes == [
        (M_MAX, R_MAX), (N_MAX, M_MAX), (N_MAX, R_MAX),
        (N_MAX,), (N_MAX, N_MAX), (N_MAX,), (M_MAX,), (R_MAX,),
    ]


def test_no_mosaic_custom_calls(built):
    """interpret=True must lower to plain HLO the CPU PJRT client can run."""
    out, manifest = built
    for name in manifest["artifacts"]:
        text = (out / f"{name}.hlo.txt").read_text()
        assert "tpu_custom_call" not in text, name
        assert "mosaic" not in text.lower(), name

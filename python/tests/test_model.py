"""Layer-2 model graph: shapes, masking invariants, utilization math."""

import numpy as np

from compile import model
from compile.kernels import BIG, M_MAX, N_MAX, R_MAX, WC_TOKENS, WC_VOCAB
from .helpers import make_instance, paper_instance


def test_scores_shapes():
    out = model.allocation_scores(*paper_instance())
    drf, tsf, ps, rps, fit, feas = [np.asarray(a) for a in out]
    assert drf.shape == (N_MAX,)
    assert tsf.shape == (N_MAX,)
    assert ps.shape == (N_MAX, M_MAX)
    assert rps.shape == (N_MAX, M_MAX)
    assert fit.shape == (N_MAX, M_MAX)
    assert feas.shape == (N_MAX, M_MAX)


def test_scores_tuple_wrapper():
    out = model.allocation_scores_tuple(*paper_instance())
    assert isinstance(out, tuple) and len(out) == 6


def test_utilization_paper_full():
    """BF-DRF's Table-1 end state: server1 cpu fully used, residuals (0,10|1,3)."""
    inst = paper_instance(x=[[20.0, 2.0], [0.0, 19.0]])
    c, x, d, _, _, _, smask, rmask = inst
    (util,) = model.cluster_utilization(c, x, d, smask, rmask)
    util = np.asarray(util)
    # total cpu used = 100 + 29 = 129 of 130; mem = 20+97 = 117 of 130
    np.testing.assert_allclose(util[0], 129.0 / 130.0, rtol=1e-5)
    np.testing.assert_allclose(util[1], 117.0 / 130.0, rtol=1e-5)
    assert np.all(util[2:] == 0.0)


def test_utilization_empty():
    inst = paper_instance()
    c, x, d, _, _, _, smask, rmask = inst
    (util,) = model.cluster_utilization(c, x, d, smask, rmask)
    np.testing.assert_allclose(np.asarray(util), 0.0)


def test_utilization_ignores_unregistered_servers():
    c = [[10.0, 10.0], [1000.0, 1000.0]]
    d = [[1.0, 1.0]]
    x = [[5.0, 0.0]]
    inst = make_instance(c, x, d)
    c_, x_, d_, _, _, _, smask, rmask = inst
    smask = smask.copy()
    smask[1] = 0.0  # pretend server 2 not registered yet (Fig 9 staging)
    (util,) = model.cluster_utilization(c_, x_, d_, smask, rmask)
    np.testing.assert_allclose(np.asarray(util)[0], 0.5, rtol=1e-6)


def test_pi_round_shape():
    (out,) = model.pi_round(np.array([5], np.int32))
    assert np.asarray(out).shape == (1,)


def test_wordcount_round_shape():
    toks = np.zeros(WC_TOKENS, np.int32)
    (out,) = model.wordcount_round(toks)
    assert np.asarray(out).shape == (WC_VOCAB,)

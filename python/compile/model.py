"""Layer-2 JAX model: the compute graphs the rust coordinator executes.

This paper's "model" is not a neural network — its compute graph is the fair
allocation scorer plus the two Spark workload bodies. Each public function
here is a jit-able JAX function calling the Layer-1 Pallas kernels; aot.py
lowers each one once to HLO text under ``artifacts/`` and the rust runtime
(rust/src/runtime/) loads and executes them via PJRT. Python never runs on
the request path.

Functions / artifacts:

* :func:`allocation_scores` -> ``artifacts/scores.hlo.txt``
* :func:`cluster_utilization` -> ``artifacts/utilization.hlo.txt``
* :func:`pi_round`          -> ``artifacts/pi_mc.hlo.txt``
* :func:`wordcount_round`   -> ``artifacts/wordcount.hlo.txt``
"""

import jax.numpy as jnp

from .kernels import BIG, M_MAX, N_MAX, PI_SAMPLES, R_MAX, WC_TOKENS, WC_VOCAB  # noqa: F401
from .kernels import pi_mc, scores, wordcount


def allocation_scores(c, x, d, phi, rolemat, fmask, smask, rmask):
    """Fused scoring pass (see kernels/scores.py).

    Inputs (padded, f32): c[M_MAX,R_MAX], x[N_MAX,M_MAX], d[N_MAX,R_MAX],
    phi[N_MAX], rolemat[N_MAX,N_MAX], fmask[N_MAX], smask[M_MAX],
    rmask[R_MAX].
    Returns (drf[N], tsf[N], psdsf[N,M], rpsdsf[N,M], fit[N,M], feas[N,M]).
    """
    return scores.allocation_scores(c, x, d, phi, rolemat, fmask, smask, rmask)


def cluster_utilization(c, x, d, smask, rmask):
    """Allocated fraction per resource — the quantity Figures 3-8 plot.

    Kept as a plain jnp graph (no Pallas): it is one einsum + reduction and
    exists so the rust trace recorder can cross-check its own bookkeeping
    against the artifact (rust/tests/runtime_parity.rs).
    """
    used = jnp.einsum("ni,nr->ir", x, d) * smask[:, None]
    cap = jnp.sum(c * smask[:, None], axis=0)
    frac = jnp.sum(used, axis=0) / jnp.maximum(cap, 1e-30)
    return (jnp.where(rmask > 0.5, frac, 0.0),)


def pi_round(seed):
    """One Spark-Pi task: int32[1] seed -> int32[1] hits of PI_SAMPLES."""
    return (pi_mc.pi_hits(seed),)


def wordcount_round(tokens):
    """One Spark-WordCount task: int32[WC_TOKENS] ids -> f32[WC_VOCAB] hist."""
    return (wordcount.wordcount_hist(tokens),)


def allocation_scores_tuple(c, x, d, phi, rolemat, fmask, smask, rmask):
    """Tuple-returning wrapper for AOT lowering (PJRT root must be a tuple)."""
    return tuple(allocation_scores(c, x, d, phi, rolemat, fmask, smask, rmask))

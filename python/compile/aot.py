"""AOT compile path: lower every Layer-2 function to HLO *text* artifacts.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and NOT
a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``). The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md and gen_hlo.py there.

Besides the ``*.hlo.txt`` files this writes ``manifest.json`` recording the
padded dimensions and each artifact's input/output shapes; the rust runtime
reads it at startup and refuses to run against stale dimensions.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import BIG, M_MAX, N_MAX, PI_SAMPLES, R_MAX, WC_TOKENS, WC_VOCAB


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_specs():
    """name -> (function, example-arg specs). Shared with tests."""
    f32, i32 = jnp.float32, jnp.int32
    return {
        "scores": (
            model.allocation_scores_tuple,
            [
                _spec((M_MAX, R_MAX), f32),   # c
                _spec((N_MAX, M_MAX), f32),   # x
                _spec((N_MAX, R_MAX), f32),   # d
                _spec((N_MAX,), f32),         # phi
                _spec((N_MAX, N_MAX), f32),   # rolemat
                _spec((N_MAX,), f32),         # fmask
                _spec((M_MAX,), f32),         # smask
                _spec((R_MAX,), f32),         # rmask
            ],
        ),
        "utilization": (
            model.cluster_utilization,
            [
                _spec((M_MAX, R_MAX), f32),
                _spec((N_MAX, M_MAX), f32),
                _spec((N_MAX, R_MAX), f32),
                _spec((M_MAX,), f32),
                _spec((R_MAX,), f32),
            ],
        ),
        "pi_mc": (model.pi_round, [_spec((1,), i32)]),
        "wordcount": (model.wordcount_round, [_spec((WC_TOKENS,), i32)]),
    }


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "dims": {
            "N_MAX": N_MAX, "M_MAX": M_MAX, "R_MAX": R_MAX,
            "PI_SAMPLES": PI_SAMPLES, "WC_TOKENS": WC_TOKENS,
            "WC_VOCAB": WC_VOCAB,
        },
        "big": BIG,
        "artifacts": {},
    }
    for name, (fn, specs) in artifact_specs().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory for *.hlo.txt + manifest.json")
    # Back-compat with the scaffold Makefile's `--out ../artifacts/model.hlo.txt`.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    lower_all(out_dir or ".")


if __name__ == "__main__":
    main()

"""Pure-jnp reference oracles for every Layer-1 kernel.

These are the correctness ground truth: straightforward, unfused, obviously
correct implementations of the same math the Pallas kernels compute. pytest
(python/tests/) sweeps random instances with hypothesis and asserts
``allclose(kernel(...), ref(...))``; the rust native scorer
(rust/src/scheduler/scorer.rs) implements the same equations and is
parity-tested against the AOT artifact in rust/tests/runtime_parity.rs.

Notation follows the paper (Shan et al. 2018):

* ``c[i, r]``   — capacity of resource ``r`` on server ``i``
* ``x[n, i]``   — tasks of framework ``n`` currently placed on server ``i``
* ``d[n, r]``   — per-task demand of framework ``n`` for resource ``r``
* ``phi[n]``    — framework weight (paper uses equal priority, phi = 1)
* ``rolemat[a, b]`` — 1.0 iff frameworks ``a`` and ``b`` belong to the same
  Mesos *role* (submission group). Fair shares aggregate over roles — the
  paper's two groups, Pi and WordCount, are "roles in Mesos' jargon" (§3.3)
  and Mesos' DRF sorter operates on roles. The identity matrix recovers
  per-framework fairness (the §2 numerical study, where each framework is
  its own role). Residuals/feasibility always use the raw per-framework x.
* ``fmask/smask/rmask`` — 1.0 where the framework / server / resource slot of
  the padded instance is real, 0.0 where it is padding.
"""

import jax.numpy as jnp

from . import BIG


def _masked(x, mask, fill):
    return jnp.where(mask > 0.5, x, fill)


def residuals(c, x, d):
    """Residual (unreserved) capacity per server/resource.

    ``res[i, r] = c[i, r] - sum_n x[n, i] * d[n, r]`` — the quantity the
    paper's Tables 3-4 report and rPS-DSF's criterion divides by.
    """
    used = jnp.einsum("ni,nr->ir", x, d)
    return c - used


def role_totals(x, rolemat, smask):
    """Role-aggregated task totals: xr[n] = sum_{n' in role(n)} x_{n'} ."""
    xn = jnp.sum(x * smask[None, :], axis=1)  # [N]
    return rolemat @ xn


def drf_shares(c, x, d, phi, rolemat, fmask, smask, rmask):
    """Global dominant shares (DRFH, [11]): s_n = max_r x_n d_{n,r} / (phi_n C_r).

    ``C_r`` is the cluster-wide capacity of resource ``r`` over *registered*
    servers. Padding frameworks score BIG so progressive filling never picks
    them; a framework with zero demand on every real resource also scores BIG
    (it can never run a task, offering it resources would loop forever).
    """
    ctot = jnp.sum(c * smask[:, None], axis=0)  # [R]
    xn = role_totals(x, rolemat, smask)  # [N] role-aggregated
    # share per resource; only real resources with positive demand count.
    valid = (rmask[None, :] > 0.5) & (d > 0.0) & (ctot[None, :] > 0.0)
    per_r = jnp.where(valid, xn[:, None] * d / (phi[:, None] * jnp.maximum(ctot[None, :], 1e-30)), -BIG)
    share = jnp.max(per_r, axis=1)
    has_demand = jnp.any(valid, axis=1)
    share = jnp.where(has_demand, share, BIG)
    return _masked(share, fmask, BIG)


def tsf_shares(c, x, d, phi, rolemat, fmask, smask, rmask):
    """Task-share fairness ([10]): share_n = x_n / (phi_n N*_n).

    ``N*_n = sum_i min_r floor(c_{i,r} / d_{n,r})`` — the whole tasks
    framework ``n`` could run were the entire cluster dedicated to it
    (integer tasking, matching the paper's progressive-filling study).
    """
    xn = role_totals(x, rolemat, smask)  # [N] role-aggregated
    valid_r = (rmask[None, None, :] > 0.5) & (d[:, None, :] > 0.0)  # [N,1,R] bcast [N,M,R]
    ratio = c[None, :, :] / jnp.maximum(d[:, None, :], 1e-30)  # [N,M,R]
    per_server = jnp.min(jnp.where(valid_r, jnp.floor(ratio), BIG), axis=2)  # [N,M]
    # a framework with no real positive demand can host "infinite" tasks -> BIG share guard below
    per_server = jnp.where(smask[None, :] > 0.5, per_server, 0.0)
    nstar = jnp.sum(jnp.where(per_server >= BIG, 0.0, per_server), axis=1)  # [N]
    share = jnp.where(nstar > 0.0, xn / (phi * jnp.maximum(nstar, 1e-30)), BIG)
    has_demand = jnp.any((d > 0.0) & (rmask[None, :] > 0.5), axis=1)
    share = jnp.where(has_demand, share, BIG)
    return _masked(share, fmask, BIG)


def psdsf_scores(c, x, d, phi, rolemat, fmask, smask, rmask):
    """Per-Server Dominant-Share Fairness ([2]): K_{n,i} = x_n max_r d_{n,r}/(phi_n c_{i,r}).

    Equivalently ``x_n / (phi_n N_{n,i})`` with ``N_{n,i}`` the (fluid) task
    count server ``i`` alone could host. A server with zero capacity on a
    demanded resource cannot host the framework at all -> BIG.
    """
    xn = role_totals(x, rolemat, smask)  # [N] role-aggregated
    valid = (rmask[None, None, :] > 0.5) & (d[:, None, :] > 0.0)  # bcast [N,M,R]
    per_r = jnp.where(
        valid & (c[None, :, :] > 0.0),
        d[:, None, :] / jnp.maximum(c[None, :, :], 1e-30),
        jnp.where(valid, BIG, -BIG),  # demanded but zero capacity -> impossible
    )
    k = jnp.max(per_r, axis=2) * xn[:, None] / phi[:, None]  # [N,M]
    impossible = jnp.any(valid & (c[None, :, :] <= 0.0), axis=2)
    has_demand = jnp.any(valid, axis=2)
    k = jnp.where(impossible | ~has_demand, BIG, k)
    k = jnp.minimum(k, BIG)
    k = _masked(k, fmask[:, None], BIG)
    k = _masked(k, smask[None, :], BIG)
    return k


def rpsdsf_scores(c, x, d, phi, rolemat, fmask, smask, rmask):
    """Residual PS-DSF (this paper, §2):

    ``K~_{n,j} = x_n max_r d_{n,r} / (phi_n (c_{j,r} - sum_n' x_{n',j} d_{n',r}))``

    i.e. PS-DSF evaluated against *current unreserved* capacities. A server
    whose residual is <= 0 on a demanded resource scores BIG (cannot take the
    next task of ``n``).
    """
    res = residuals(c, x, d)  # [M,R]
    xn = role_totals(x, rolemat, smask)
    valid = (rmask[None, None, :] > 0.5) & (d[:, None, :] > 0.0)
    per_r = jnp.where(
        valid & (res[None, :, :] > 0.0),
        d[:, None, :] / jnp.maximum(res[None, :, :], 1e-30),
        jnp.where(valid, BIG, -BIG),
    )
    k = jnp.max(per_r, axis=2) * xn[:, None] / phi[:, None]
    exhausted = jnp.any(valid & (res[None, :, :] <= 0.0), axis=2)
    has_demand = jnp.any(valid, axis=2)
    k = jnp.where(exhausted | ~has_demand, BIG, k)
    k = jnp.minimum(k, BIG)
    k = _masked(k, fmask[:, None], BIG)
    k = _masked(k, smask[None, :], BIG)
    return k


def bestfit_ratio(c, x, d, fmask, smask, rmask):
    """Best-fit server-selection score ([11] via BF-DRF):

    ``fit[n, i] = max_r d[n, r] / res[i, r]`` — the reciprocal of how many
    further tasks of ``n`` server ``i``'s residual could host. BF-DRF picks
    the framework by DRF and then the feasible server *minimizing* this
    ratio: the server whose residual profile "most closely matches the
    demands" is the one where no single resource dimension chokes the
    demand vector. (Minimizing an L1 distance instead sends memory-bound
    frameworks to CPU-rich servers and fails to reproduce Table 1 — kept as
    an ablation in rust/benches/ablations.rs.) BIG when one more task does
    not fit at all. Note rPS-DSF's score is exactly ``x_n/phi_n`` times this
    ratio — the fused kernel computes it once.
    """
    res = residuals(c, x, d)  # [M,R]
    valid = (rmask[None, None, :] > 0.5) & (d[:, None, :] > 0.0)
    per_r = jnp.where(
        valid & (res[None, :, :] > 0.0),
        d[:, None, :] / jnp.maximum(res[None, :, :], 1e-30),
        jnp.where(valid, BIG, -BIG),
    )
    fit = jnp.max(per_r, axis=2)
    fit = jnp.minimum(fit, BIG)
    feas = feasibility(c, x, d, fmask, smask, rmask) > 0.5
    fit = jnp.where(feas, fit, BIG)
    return fit


def feasibility(c, x, d, fmask, smask, rmask):
    """1.0 where one more task of framework ``n`` fits server ``i``'s residual.

    A small epsilon absorbs f32 rounding from the einsum (capacities and
    demands are exact small numbers, so 1e-4 is conservative).
    """
    res = residuals(c, x, d)
    ok_r = (res[None, :, :] + 1e-4 >= d[:, None, :]) | (rmask[None, None, :] < 0.5)
    has_demand = jnp.any((d > 0.0) & (rmask[None, :] > 0.5), axis=1)  # [N]
    ok = jnp.all(ok_r, axis=2) & (fmask[:, None] > 0.5) & (smask[None, :] > 0.5)
    ok = ok & has_demand[:, None]
    return ok.astype(jnp.float32)


def allocation_scores(c, x, d, phi, rolemat, fmask, smask, rmask):
    """All six score tensors, in the order the AOT artifact returns them."""
    return (
        drf_shares(c, x, d, phi, rolemat, fmask, smask, rmask),
        tsf_shares(c, x, d, phi, rolemat, fmask, smask, rmask),
        psdsf_scores(c, x, d, phi, rolemat, fmask, smask, rmask),
        rpsdsf_scores(c, x, d, phi, rolemat, fmask, smask, rmask),
        bestfit_ratio(c, x, d, fmask, smask, rmask),
        feasibility(c, x, d, fmask, smask, rmask),
    )


def utilization(c, x, d, smask, rmask):
    """Cluster-level allocated fraction per resource: the quantity Figures 3-8
    plot (``allocated CPU %``, ``allocated memory %``)."""
    used = jnp.einsum("ni,nr->ir", x, d) * smask[:, None]
    cap = jnp.sum(c * smask[:, None], axis=0)
    frac = jnp.sum(used, axis=0) / jnp.maximum(cap, 1e-30)
    return jnp.where(rmask > 0.5, frac, 0.0)


# --- workload kernels -------------------------------------------------------

def _mix(h):
    """32-bit finalizer (murmur3 fmix32): a counter-based PRNG good enough for
    Monte-Carlo pi — passes the chi-square smoke test in test_pi.py."""
    h = jnp.uint32(h)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def pi_hits(seed, n_samples):
    """Count Monte-Carlo points inside the quarter circle.

    ``seed`` is an int32[1]; returns int32[1] hit count out of ``n_samples``.
    x/y coordinates come from two decorrelated lanes of the counter hash.
    """
    i = jnp.arange(n_samples, dtype=jnp.uint32)
    s = seed[0].astype(jnp.uint32)
    hx = _mix(i * jnp.uint32(0x9E3779B9) + s)
    hy = _mix(i * jnp.uint32(0x85EBCA77) + s + jnp.uint32(0x6C62272E))
    fx = hx.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
    fy = hy.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
    inside = (fx * fx + fy * fy) < 1.0
    return jnp.sum(inside.astype(jnp.int32)).reshape(1)


def wordcount_hist(tokens, vocab):
    """Token-id histogram: hist[v] = |{t : tokens[t] == v}| as float32[V].

    Out-of-range ids (< 0 or >= vocab) are ignored, matching the rust-side
    tokenizer contract (it clamps real hash buckets into range, so in
    practice nothing is dropped).
    """
    v = jnp.arange(vocab, dtype=jnp.int32)
    onehot = (tokens[:, None] == v[None, :]).astype(jnp.float32)
    return jnp.sum(onehot, axis=0)

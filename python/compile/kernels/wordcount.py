"""WordCount histogram kernel — the Spark-WordCount task body.

The paper's ``WordCount`` group counts words of a 700 MB+ document (§3.3).
Each simulated Spark task processes one chunk of the corpus: the rust driver
tokenizes its chunk into hashed token ids (rust/src/runtime/workload.rs) and
this kernel produces the per-chunk histogram; the driver then reduces
histograms across tasks — exactly Spark's map-side count + shuffle-reduce
structure, with the map-side combine living on the accelerator.

MXU adaptation (DESIGN.md §Hardware-Adaptation): the histogram is computed as
``ones[1,T] @ onehot[T,V]`` so the reduction over tokens is a matmul the
systolic array executes, rather than a scatter (which TPUs do poorly). With
T = 2048, V = 512 the onehot tile is 4 MiB f32 (bf16-able to 2 MiB), well
inside VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import WC_TOKENS, WC_VOCAB


def _wc_kernel(tok_ref, out_ref):
    tokens = tok_ref[...]                                   # i32[T]
    v = jax.lax.broadcasted_iota(jnp.int32, (WC_TOKENS, WC_VOCAB), 1)
    onehot = (tokens[:, None] == v).astype(jnp.float32)     # [T,V]
    ones = jnp.ones((1, WC_TOKENS), dtype=jnp.float32)
    hist = jnp.dot(ones, onehot)                            # [1,V] on the MXU
    out_ref[...] = hist[0]


@functools.partial(jax.jit)
def wordcount_hist(tokens):
    """int32[WC_TOKENS] token ids -> float32[WC_VOCAB] histogram.

    Ids outside [0, WC_VOCAB) simply match no bucket (the rust tokenizer
    hashes into range, so nothing is dropped in practice; pad slots use -1).
    """
    return pl.pallas_call(
        _wc_kernel,
        out_shape=jax.ShapeDtypeStruct((WC_VOCAB,), jnp.float32),
        interpret=True,
    )(tokens.astype(jnp.int32))

"""Monte-Carlo pi kernel — the Spark-Pi task body.

The paper's ``Pi`` submission group runs jobs that "accurately calculate
pi = 3.1415... via Monte Carlo simulation" (§3.3). Each simulated Spark task
in the e2e example executes one round of this kernel through the AOT/PJRT
path: given a task-unique seed, generate ``PI_SAMPLES`` pseudo-random points
in the unit square with a counter-based hash PRNG and count how many fall
inside the quarter circle. The driver aggregates hit counts across tasks and
reports ``4 * hits / samples``.

Counter-based (stateless) RNG is the TPU-native choice: no sequential state,
purely element-wise VPU work over an iota, trivially vectorizable. The hash
is murmur3's fmix32 finalizer over decorrelated lane counters.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import PI_SAMPLES


def _mix(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _pi_kernel(seed_ref, out_ref):
    s = seed_ref[0].astype(jnp.uint32)
    i = jax.lax.broadcasted_iota(jnp.uint32, (PI_SAMPLES,), 0)
    hx = _mix(i * jnp.uint32(0x9E3779B9) + s)
    hy = _mix(i * jnp.uint32(0x85EBCA77) + s + jnp.uint32(0x6C62272E))
    inv = jnp.float32(1.0 / 4294967296.0)
    fx = hx.astype(jnp.float32) * inv
    fy = hy.astype(jnp.float32) * inv
    inside = (fx * fx + fy * fy) < 1.0
    out_ref[0] = jnp.sum(inside.astype(jnp.int32))


@functools.partial(jax.jit)
def pi_hits(seed):
    """int32[1] seed -> int32[1] quarter-circle hit count out of PI_SAMPLES."""
    return pl.pallas_call(
        _pi_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        interpret=True,
    )(seed.astype(jnp.int32))

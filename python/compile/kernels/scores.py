"""Fused fair-allocation scoring as a single Pallas kernel.

The allocator's hot-spot is: given the current cluster state (capacities
``c``, allocations ``x``, demands ``d``), produce the score tensors every
fairness criterion needs so the coordinator can argmin over them. The paper
evaluates five criteria (DRF, TSF, PS-DSF, rPS-DSF, BF-DRF); recomputing
residuals/dominant ratios per criterion wastes bandwidth, so this kernel does
one fused pass over the padded (N_MAX, M_MAX, R_MAX) instance and emits all
six tensors at once.

VMEM/tiling story (DESIGN.md §Hardware-Adaptation): the whole instance is
tiny — every tensor is at most N_MAX*M_MAX*R_MAX = 512 f32 = 2 KiB — so the
kernel uses a single grid step with all operands resident in VMEM; there is
no HBM<->VMEM schedule to pipeline. The win on real hardware is fusion (one
pass over x/c/d instead of six) rather than tiling.

interpret=True throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness — not CPU wallclock — is what the interpret
path validates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import BIG, M_MAX, N_MAX, R_MAX


def _scores_kernel(c_ref, x_ref, d_ref, phi_ref, rolemat_ref, fmask_ref, smask_ref, rmask_ref,
                   drf_ref, tsf_ref, ps_ref, rps_ref, fit_ref, feas_ref):
    c = c_ref[...]        # [M,R] capacities
    x = x_ref[...]        # [N,M] current integer allocations (as f32)
    d = d_ref[...]        # [N,R] per-task demands
    phi = phi_ref[...]    # [N]   weights
    rolemat = rolemat_ref[...]  # [N,N] role membership (identity = per-framework)
    fmask = fmask_ref[...]
    smask = smask_ref[...]
    rmask = rmask_ref[...]

    big = jnp.float32(BIG)
    eps = jnp.float32(1e-30)

    # --- shared intermediates (the point of fusing) -------------------------
    # x_n: role-aggregated task totals over registered servers (Mesos' DRF
    # sorter operates on roles; identity rolemat = per-framework fairness).
    xn = rolemat @ jnp.sum(x * smask[None, :], axis=1)             # [N]
    # residual (unreserved) capacity per server/resource.
    used = jnp.einsum("ni,nr->ir", x, d)                           # [M,R]
    res = c - used                                                 # [M,R]
    # demand validity per (n, r) and broadcast to (n, i, r).
    dvalid = (rmask[None, :] > 0.5) & (d > 0.0)                    # [N,R]
    valid3 = dvalid[:, None, :]                                    # [N,1,R] -> bcast
    has_demand = jnp.any(dvalid, axis=1)                           # [N]

    # --- DRF: global dominant share -----------------------------------------
    ctot = jnp.sum(c * smask[:, None], axis=0)                     # [R]
    drf_valid = dvalid & (ctot[None, :] > 0.0)
    drf_per_r = jnp.where(drf_valid,
                          xn[:, None] * d / (phi[:, None] * jnp.maximum(ctot[None, :], eps)),
                          -big)
    drf = jnp.max(drf_per_r, axis=1)
    drf = jnp.where(jnp.any(drf_valid, axis=1), drf, big)
    drf = jnp.where(fmask > 0.5, drf, big)

    # --- TSF: x_n / N*_n with N*_n = sum_i min_r floor(c_ir / d_nr) ----------
    ratio = c[None, :, :] / jnp.maximum(d[:, None, :], eps)        # [N,M,R]
    per_server = jnp.min(jnp.where(valid3, jnp.floor(ratio), big), axis=2)  # [N,M]
    per_server = jnp.where(smask[None, :] > 0.5, per_server, 0.0)
    nstar = jnp.sum(jnp.where(per_server >= big, 0.0, per_server), axis=1)  # [N]
    tsf = jnp.where(nstar > 0.0, xn / (phi * jnp.maximum(nstar, eps)), big)
    tsf = jnp.where(has_demand, tsf, big)
    tsf = jnp.where(fmask > 0.5, tsf, big)

    # --- PS-DSF: K_{n,i} = x_n max_r d_nr / (phi_n c_ir) ---------------------
    ps_per_r = jnp.where(valid3 & (c[None, :, :] > 0.0),
                         d[:, None, :] / jnp.maximum(c[None, :, :], eps),
                         jnp.where(valid3, big, -big))
    ps = jnp.max(ps_per_r, axis=2) * xn[:, None] / phi[:, None]    # [N,M]
    ps_impossible = jnp.any(valid3 & (c[None, :, :] <= 0.0), axis=2)
    ps = jnp.where(ps_impossible | ~has_demand[:, None], big, ps)
    ps = jnp.minimum(ps, big)
    ps = jnp.where(fmask[:, None] > 0.5, ps, big)
    ps = jnp.where(smask[None, :] > 0.5, ps, big)

    # --- residual demand/supply ratio (shared by rPS-DSF and best-fit) ------
    # ratio[n,i] = max_r d_nr / res_ir : the reciprocal of how many further
    # tasks of n server i could host. rPS-DSF = x_n/phi_n * ratio; BF-DRF's
    # best-fit server is the feasible argmin of the ratio itself.
    ratio_per_r = jnp.where(valid3 & (res[None, :, :] > 0.0),
                            d[:, None, :] / jnp.maximum(res[None, :, :], eps),
                            jnp.where(valid3, big, -big))
    ratio = jnp.max(ratio_per_r, axis=2)                           # [N,M]
    exhausted = jnp.any(valid3 & (res[None, :, :] <= 0.0), axis=2)
    ratio = jnp.where(exhausted | ~has_demand[:, None], big, ratio)
    ratio = jnp.minimum(ratio, big)
    ratio = jnp.where(fmask[:, None] > 0.5, ratio, big)
    ratio = jnp.where(smask[None, :] > 0.5, ratio, big)

    # --- rPS-DSF: ratio scaled by the framework's weighted total tasks -------
    rps = ratio * xn[:, None] / phi[:, None]
    rps = jnp.where(ratio >= big, big, rps)
    rps = jnp.minimum(rps, big)

    # --- feasibility + best-fit ratio ----------------------------------------
    ok_r = (res[None, :, :] + jnp.float32(1e-4) >= d[:, None, :]) | (rmask[None, None, :] < 0.5)
    feas = (jnp.all(ok_r, axis=2)
            & (fmask[:, None] > 0.5) & (smask[None, :] > 0.5)
            & has_demand[:, None])
    fit = jnp.where(feas, ratio, big)

    drf_ref[...] = drf
    tsf_ref[...] = tsf
    ps_ref[...] = ps
    rps_ref[...] = rps
    fit_ref[...] = fit
    feas_ref[...] = feas.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def allocation_scores(c, x, d, phi, rolemat, fmask, smask, rmask):
    """Pallas entry point; shapes are the padded constants from ``kernels``.

    Returns ``(drf[N], tsf[N], psdsf[N,M], rpsdsf[N,M], fit[N,M], feas[N,M])``
    — exactly what :func:`kernels.ref.allocation_scores` computes unfused.
    """
    f32 = jnp.float32
    out_shape = (
        jax.ShapeDtypeStruct((N_MAX,), f32),
        jax.ShapeDtypeStruct((N_MAX,), f32),
        jax.ShapeDtypeStruct((N_MAX, M_MAX), f32),
        jax.ShapeDtypeStruct((N_MAX, M_MAX), f32),
        jax.ShapeDtypeStruct((N_MAX, M_MAX), f32),
        jax.ShapeDtypeStruct((N_MAX, M_MAX), f32),
    )
    return pl.pallas_call(
        _scores_kernel,
        out_shape=out_shape,
        interpret=True,
    )(c.astype(f32), x.astype(f32), d.astype(f32), phi.astype(f32),
      rolemat.astype(f32), fmask.astype(f32), smask.astype(f32), rmask.astype(f32))

"""Layer-1 Pallas kernels for mesos-fair.

Three kernels, all lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls; see /opt/xla-example/README.md):

* :mod:`scores`    — fused fair-allocation scoring (DRF, TSF, PS-DSF,
                     rPS-DSF, best-fit distance, feasibility) over a padded
                     (N_MAX, M_MAX, R_MAX) cluster instance.
* :mod:`pi_mc`     — Monte-Carlo quarter-circle hit counting (the Spark-Pi
                     task body) with a counter-based PCG-style hash PRNG.
* :mod:`wordcount` — token-id histogram via a [1,T]x[T,V] matmul reduction
                     (the Spark-WordCount task body).

:mod:`ref` holds the pure-jnp oracles pytest checks every kernel against.
"""

# Padded problem dimensions shared by the scores kernel, the L2 model, the
# AOT artifacts and the rust runtime (rust/src/runtime/scorer.rs keeps the
# mirror constants; python/tests/test_aot.py checks the manifest).
N_MAX = 16  # frameworks
M_MAX = 8   # servers / agents
R_MAX = 4   # resource kinds

# Workload-kernel dimensions.
PI_SAMPLES = 16384  # Monte-Carlo points per pi_mc round
WC_TOKENS = 2048    # tokens per wordcount round
WC_VOCAB = 512      # histogram buckets

# Finite stand-in for +inf inside score tensors: keeps HLO free of inf/nan
# edge cases and lets the rust side compare with plain f32 ordering.
BIG = 1.0e30

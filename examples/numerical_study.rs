//! The §2 numerical study: progressive filling with integer tasking on the
//! two-framework / two-server illustrative example — regenerates Tables 1-4
//! with the paper's reference values inline.
//!
//! ```sh
//! cargo run --release --example numerical_study -- [trials] [seed]
//! ```

use mesos_fair::exp::tables::run_illustrative;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let trials: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(200);
    let seed: u64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(0x5EED);

    let t0 = std::time::Instant::now();
    let t = run_illustrative(trials, seed);
    println!("{}", t.render());
    println!("({} trials of 3 RRR schedulers + 3 deterministic runs in {:.0}ms)",
             trials, 1e3 * t0.elapsed().as_secs_f64());

    // also dump CSV next to the binary for plotting
    let path = "target/numerical_study.csv";
    if t.to_csv().write_to(path).is_ok() {
        println!("wrote {path}");
    }
}

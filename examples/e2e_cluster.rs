//! END-TO-END driver: the full three-layer stack on a real small workload.
//!
//! Every winning Spark task executes its actual body through the AOT/PJRT
//! path (Layer 1 Pallas kernels, Layer 2 JAX graphs, compiled once, run
//! from rust): Pi tasks run Monte-Carlo rounds, WordCount tasks histogram
//! synthetic corpus chunks. The allocator itself scores through the
//! AOT-compiled fused kernel (`HloScorer`) — so both the *control plane*
//! and the *data plane* of this run exercise artifacts built by
//! `make artifacts`. Python is not involved.
//!
//! Reported: batch makespan (simulated), real task-execution
//! latency/throughput (wall), the aggregated π estimate and wordcount
//! output, and scorer parity. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_cluster -- [jobs_per_queue]
//! ```

use mesos_fair::error::Result;
use mesos_fair::mesos::AllocatorMode;
use mesos_fair::runtime::{ArtifactRuntime, HloScorer, WorkloadRuntime};
use mesos_fair::sim::online::{OnlineConfig, OnlineSim};

fn main() -> Result<()> {
    let jobs: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(3);

    println!("e2e: rPS-DSF allocator (HLO-scored) + real PJRT task compute");
    let rt = ArtifactRuntime::open_default()?;
    println!("PJRT platform: {}\n", rt.platform());
    let scorer = HloScorer::new(rt);

    let mut cfg = OnlineConfig::paper("rpsdsf", AllocatorMode::Characterized, jobs);
    for q in &mut cfg.queues {
        q.workload.tasks_per_job = 16;
    }
    cfg.seed = 0xE2E;

    let mut compute = WorkloadRuntime::open_default()?;
    let t0 = std::time::Instant::now();
    let r = OnlineSim::with_scorer(cfg, Box::new(scorer))?.run_with_compute(&mut compute)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("--- scheduling (simulated cluster) ---");
    println!("jobs completed : {}", r.jobs_completed);
    println!("tasks executed : {}", r.tasks_done);
    println!("makespan       : {:.1}s simulated", r.makespan);
    println!(
        "utilization    : cpu {:.1}%±{:.1}, mem {:.1}%±{:.1}",
        100.0 * r.mean_cpu,
        100.0 * r.std_cpu,
        100.0 * r.mean_mem,
        100.0 * r.std_mem
    );
    println!("allocator      : {} cycles, {} grants (all scored via PJRT)", r.cycles, r.grants);

    println!("\n--- real compute (Layer-1 kernels via PJRT) ---");
    println!("pi rounds      : {} x {} samples", compute.pi_rounds, mesos_fair::PI_SAMPLES);
    println!(
        "pi estimate    : {:.6}  (true pi {:.6}, err {:+.2e})",
        compute.pi_estimate(),
        std::f64::consts::PI,
        compute.pi_estimate() - std::f64::consts::PI
    );
    println!("wc tokens      : {}", compute.tokens);
    println!("wc top buckets : {:?}", compute.top_buckets(5));
    assert!(compute.histogram_consistent(), "wordcount histogram lost tokens");

    let n = compute.latency.count();
    println!("\n--- end-to-end performance (wall clock) ---");
    println!("task execs     : {n}");
    println!(
        "task latency   : mean {:.3}ms ± {:.3}ms",
        1e3 * compute.latency.mean(),
        1e3 * compute.latency.stddev()
    );
    println!("task throughput: {:.0} execs/s", n as f64 / wall.max(1e-9));
    println!("total wall     : {wall:.2}s");

    // hard checks: this example doubles as the e2e validation gate
    assert_eq!(r.jobs_completed, 10 * jobs);
    assert!((compute.pi_estimate() - std::f64::consts::PI).abs() < 0.01);
    println!("\ne2e OK: all layers composed (rust coordinator -> PJRT -> AOT pallas kernels).");
    Ok(())
}

//! The Figure-9 scenario (§3.7): three servers, one of each type, register
//! one by one — forcing an initially suboptimal allocation — and we watch
//! whether the scheduler recovers. rPS-DSF adapts (its criterion tracks
//! current residuals); BF-DRF keeps re-offering the same agent to the same
//! framework.
//!
//! ```sh
//! cargo run --release --example staged_registration -- [jobs_per_queue]
//! ```

use mesos_fair::exp::fig9;
use mesos_fair::metrics::plot;

fn main() -> mesos_fair::error::Result<()> {
    let jobs: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(20);
    println!("staged registration (type-1 -> type-2 -> type-3), 10 queues x {jobs} jobs\n");

    let fig = fig9::run(jobs, 0x5EED)?;
    println!("Allocated memory fraction over time:");
    let series: Vec<_> = fig.runs.iter().map(|r| &r.trace.mem).collect();
    println!("{}", plot::render(&series, 72, 14, 1.0));

    for r in &fig.runs {
        println!(
            "{:28} makespan {:7.1}s   mem {:5.1}%±{:4.1}",
            r.label,
            r.makespan,
            100.0 * r.mean_mem,
            100.0 * r.std_mem
        );
    }
    let bf = fig9::mid_run_mem_efficiency(&fig, "bf-drf").unwrap();
    let rps = fig9::mid_run_mem_efficiency(&fig, "rpsdsf").unwrap();
    println!("\nmid-run memory efficiency: rPS-DSF {:.1}% vs BF-DRF {:.1}%", 100.0 * rps, 100.0 * bf);
    if rps > bf {
        println!("=> rPS-DSF recovered from the suboptimal start; BF-DRF did not (paper Fig. 9).");
    } else {
        println!("=> shapes did not separate at this batch size; try more jobs.");
    }
    Ok(())
}

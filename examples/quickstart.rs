//! Quickstart: schedule a small Spark job batch on a heterogeneous
//! Mesos-like cluster with the paper's rPS-DSF allocator, and compare it to
//! stock DRF.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mesos_fair::mesos::AllocatorMode;
use mesos_fair::metrics::plot;
use mesos_fair::sim::online::{OnlineConfig, OnlineSim};

fn main() -> mesos_fair::error::Result<()> {
    println!("mesos-fair quickstart — 2 Pi + 2 WordCount queues x 4 jobs, 6 heterogeneous agents\n");

    let mut results = Vec::new();
    for policy in ["drf", "rpsdsf"] {
        // the paper's cluster (2x type-1, 2x type-2, 2x type-3) with a small batch
        let mut cfg = OnlineConfig::paper(policy, AllocatorMode::Characterized, 4);
        cfg.queues.truncate(7);
        cfg.queues.drain(2..5); // keep 2 Pi + 2 WordCount queues
        cfg.seed = 42;
        let result = OnlineSim::new(cfg)?.run()?;
        println!(
            "{:22} makespan {:7.1}s   mean cpu {:5.1}%   mean mem {:5.1}%   ({} jobs, {} executor grants)",
            result.label,
            result.makespan,
            100.0 * result.mean_cpu,
            100.0 * result.mean_mem,
            result.jobs_completed,
            result.grants,
        );
        results.push(result);
    }

    println!("\nAllocated CPU fraction over time:");
    let series: Vec<_> = results.iter().map(|r| &r.trace.cpu).collect();
    println!("{}", plot::render(&series, 72, 12, 1.0));

    let speedup = results[0].makespan / results[1].makespan;
    println!("rPS-DSF finished the same batch {speedup:.2}x faster than DRF on this heterogeneous cluster.");
    println!("(Run `mesos-fair tables` and `cargo bench` for the full paper reproduction.)");
    Ok(())
}

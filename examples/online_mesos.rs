//! Online Mesos experiment driver: run any scheduler/mode combination on
//! the paper's cluster and print the figure-style trace.
//!
//! ```sh
//! cargo run --release --example online_mesos -- --scheduler psdsf --mode characterized
//! cargo run --release --example online_mesos -- --scheduler drf --mode oblivious --jobs 10
//! cargo run --release --example online_mesos -- --scheduler drf --homogeneous --jobs 10
//! ```

use mesos_fair::cli::Args;
use mesos_fair::error::{Error, Result};
use mesos_fair::mesos::AllocatorMode;
use mesos_fair::metrics::plot;
use mesos_fair::sim::online::{OnlineConfig, OnlineSim};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let policy = args.flag_or("scheduler", "rrr-psdsf");
    let mode = match args.flag_or("mode", "characterized").as_str() {
        "oblivious" => AllocatorMode::Oblivious,
        "characterized" => AllocatorMode::Characterized,
        other => return Err(Error::Config(format!("unknown mode '{other}'"))),
    };
    let jobs = args.flag_usize("jobs", 20)?;
    let mut cfg = if args.has("homogeneous") {
        OnlineConfig::paper_homogeneous(&policy, mode, jobs)
    } else {
        OnlineConfig::paper(&policy, mode, jobs)
    };
    cfg.seed = args.flag_u64("seed", 0x5EED)?;

    println!(
        "online experiment: {policy}/{} on {} agents, 10 queues x {jobs} jobs\n",
        mode.label(),
        cfg.cluster.len()
    );
    let t0 = std::time::Instant::now();
    let r = OnlineSim::new(cfg)?.run()?;
    println!("Allocated CPU and memory fractions over time:");
    println!("{}", plot::render(&[&r.trace.cpu, &r.trace.mem], 72, 14, 1.0));
    println!("jobs completed : {}", r.jobs_completed);
    println!("tasks executed : {}", r.tasks_done);
    println!("makespan       : {:.1}s (simulated)", r.makespan);
    for (g, t) in &r.group_finish {
        println!("group {g:10} : done at {t:.1}s");
    }
    println!(
        "utilization    : cpu {:.1}%±{:.1}, mem {:.1}%±{:.1}",
        100.0 * r.mean_cpu,
        100.0 * r.std_cpu,
        100.0 * r.mean_mem,
        100.0 * r.std_mem
    );
    println!("allocator      : {} cycles, {} grants", r.cycles, r.grants);
    println!("wall time      : {:.2}s", t0.elapsed().as_secs_f64());
    Ok(())
}
